//! Exact Top-k selection (paper Eq. 4).
//!
//! `TopK(x, k)_i = x_i if |x_i| >= thr else 0`, where `thr` is the k-th
//! largest |x_i|. Matches `ref.kth_largest_abs` / `ref.topk_ref` including
//! the tie behaviour: every element with |x_i| == thr is kept, so at least
//! `k` elements survive.

/// The k-th largest |x_i| (k is 1-based). O(n) via quickselect.
/// k == 0 returns +inf (select nothing); k >= n returns the min |x|.
pub fn kth_largest_abs(x: &[f32], k: usize) -> f32 {
    let mut buf = Vec::new();
    kth_largest_abs_with_buf(x, k, &mut buf)
}

/// Allocation-free variant for hot loops: `buf` is a reusable scratch
/// vector (cleared and refilled with |x|). ~2x faster than the allocating
/// form on the trainer's per-layer cadence (EXPERIMENTS.md §Perf L3-1).
pub fn kth_largest_abs_with_buf(x: &[f32], k: usize, buf: &mut Vec<f32>) -> f32 {
    if k == 0 {
        return f32::INFINITY;
    }
    let n = x.len();
    if n == 0 {
        return f32::INFINITY;
    }
    let k = k.min(n);
    buf.clear();
    buf.extend(x.iter().map(|v| v.abs()));
    // k-th largest == (n-k)-th smallest (0-based); total_cmp avoids the
    // partial_cmp unwrap branch in the comparator
    let idx = n - k;
    let (_, kth, _) = buf.select_nth_unstable_by(idx, f32::total_cmp);
    *kth
}

/// Dense-masked TopK: keep |x_i| >= thr(k), zero the rest. Returns (masked,
/// threshold).
pub fn topk_mask(x: &[f32], k: usize) -> (Vec<f32>, f32) {
    let mut out = vec![0.0f32; x.len()];
    let thr = topk_mask_into(x, k, &mut out);
    (out, thr)
}

/// Allocation-free variant for the trainer hot loop; writes into `out`
/// (must be the same length as `x`), returns the threshold.
pub fn topk_mask_into(x: &[f32], k: usize, out: &mut [f32]) -> f32 {
    debug_assert_eq!(x.len(), out.len());
    let thr = kth_largest_abs(x, k);
    mask_with_threshold(x, thr, out);
    thr
}

/// All-ones bitmask when `|v| >= thr`, zero otherwise (NaN `v` or NaN
/// `thr` select zero, matching the branchy `if v.abs() >= thr` form).
#[inline(always)]
fn keep_mask(v: f32, thr: f32) -> u32 {
    ((v.abs() >= thr) as u32).wrapping_neg()
}

const LANES: usize = 8;

/// Apply a precomputed threshold: out_i = x_i if |x_i| >= thr else 0.
///
/// Runs per layer per worker per step (the masked compress path and the
/// XLA host emulation); dispatches through the process-wide
/// [`crate::runtime::simd::KernelSet`] — every ISA path is bit-identical
/// to [`mask_with_threshold_scalar`], including NaN/±inf handling and the
/// literal `+0.0` written for dropped elements.
pub fn mask_with_threshold(x: &[f32], thr: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    crate::runtime::simd::active().mask_with_threshold(x, thr, out);
}

/// The PR-5 branchless scalar kernel, verbatim — the bit-exactness
/// reference for every SIMD mask path (and the scalar `KernelSet` member):
/// bitmask select, chunk-unrolled by [`LANES`] so the loop autovectorizes.
pub(crate) fn mask_with_threshold_scalar(x: &[f32], thr: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for (xs, os) in (&mut xc).zip(&mut oc) {
        for i in 0..LANES {
            let v = xs[i];
            os[i] = f32::from_bits(v.to_bits() & keep_mask(v, thr));
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder().iter()) {
        *o = f32::from_bits(v.to_bits() & keep_mask(v, thr));
    }
}

/// Split x at the threshold: `kept` gets the TopK part, `resid` gets the
/// complement (kept + resid == x elementwise). The error-feedback hot
/// path; dispatches like [`mask_with_threshold`].
pub fn split_with_threshold(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
    debug_assert_eq!(x.len(), kept.len());
    debug_assert_eq!(x.len(), resid.len());
    crate::runtime::simd::active().split_with_threshold(x, thr, kept, resid);
}

/// The PR-5 branchless scalar split, verbatim — the bit-exactness
/// reference for every SIMD split path (and the scalar `KernelSet`
/// member).
pub(crate) fn split_with_threshold_scalar(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
    debug_assert_eq!(x.len(), kept.len());
    debug_assert_eq!(x.len(), resid.len());
    let mut xc = x.chunks_exact(LANES);
    let mut kc = kept.chunks_exact_mut(LANES);
    let mut rc = resid.chunks_exact_mut(LANES);
    for ((xs, ks), rs) in (&mut xc).zip(&mut kc).zip(&mut rc) {
        for i in 0..LANES {
            let v = xs[i];
            let m = keep_mask(v, thr);
            ks[i] = f32::from_bits(v.to_bits() & m);
            rs[i] = f32::from_bits(v.to_bits() & !m);
        }
    }
    let (xt, kt, rt) = (xc.remainder(), kc.into_remainder(), rc.into_remainder());
    for i in 0..xt.len() {
        let v = xt[i];
        let m = keep_mask(v, thr);
        kt[i] = f32::from_bits(v.to_bits() & m);
        rt[i] = f32::from_bits(v.to_bits() & !m);
    }
}

/// Number of elements that survive a threshold.
pub fn count_kept(x: &[f32], thr: f32) -> usize {
    x.iter().filter(|v| v.abs() >= thr).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn kth_matches_sort() {
        let x = randvec(257, 1);
        for &k in &[1usize, 2, 16, 128, 256, 257] {
            let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let expect = mags[mags.len() - k];
            assert_eq!(kth_largest_abs(&x, k), expect, "k={k}");
        }
    }

    #[test]
    fn k_zero_selects_nothing() {
        let x = randvec(16, 2);
        let (m, thr) = topk_mask(&x, 0);
        assert!(thr.is_infinite());
        assert!(m.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn k_full_selects_everything() {
        let x = randvec(64, 3);
        let (m, _) = topk_mask(&x, 64);
        assert_eq!(m, x);
        let (m2, _) = topk_mask(&x, 1000); // k > n clamps
        assert_eq!(m2, x);
    }

    #[test]
    fn keeps_at_least_k() {
        let x = randvec(1024, 4);
        for &k in &[1usize, 10, 100, 1000] {
            let (m, _) = topk_mask(&x, k);
            assert!(m.iter().filter(|&&v| v != 0.0).count() >= k);
        }
    }

    #[test]
    fn kept_dominates_dropped() {
        let x = randvec(512, 5);
        let (m, thr) = topk_mask(&x, 32);
        for (i, &v) in x.iter().enumerate() {
            if m[i] != 0.0 {
                assert!(v.abs() >= thr);
                assert_eq!(m[i], v);
            } else {
                assert!(v.abs() < thr);
            }
        }
    }

    #[test]
    fn ties_all_kept() {
        let x = vec![1.0f32, -1.0, 1.0, 0.5, -1.0];
        let (m, thr) = topk_mask(&x, 2);
        assert_eq!(thr, 1.0);
        assert_eq!(m, vec![1.0, -1.0, 1.0, 0.0, -1.0]); // 4 kept (ties)
    }

    #[test]
    fn split_conserves_mass() {
        let x = randvec(300, 6);
        let thr = kth_largest_abs(&x, 30);
        let mut kept = vec![0.0; 300];
        let mut resid = vec![0.0; 300];
        split_with_threshold(&x, thr, &mut kept, &mut resid);
        for i in 0..300 {
            assert_eq!(kept[i] + resid[i], x[i]);
            assert!(kept[i] == 0.0 || resid[i] == 0.0);
        }
        assert_eq!(count_kept(&x, thr), kept.iter().filter(|&&v| v != 0.0).count());
    }

    #[test]
    fn empty_input() {
        let (m, thr) = topk_mask(&[], 5);
        assert!(m.is_empty());
        assert!(thr.is_infinite());
    }

    #[test]
    fn branchless_kernels_match_branchy_reference() {
        // every remainder length around the unroll width, plus specials
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 250] {
            let mut x = randvec(n, 40 + n as u64);
            if n >= 4 {
                x[0] = f32::NAN;
                x[1] = f32::INFINITY;
                x[2] = -0.0;
                x[3] = 0.0;
            }
            for thr in [0.0f32, 0.5, f32::INFINITY, f32::NAN] {
                let mut masked = vec![9.0f32; n];
                mask_with_threshold(&x, thr, &mut masked);
                let mut kept = vec![9.0f32; n];
                let mut resid = vec![9.0f32; n];
                split_with_threshold(&x, thr, &mut kept, &mut resid);
                for i in 0..n {
                    let keep = x[i].abs() >= thr;
                    let expect_mask = if keep { x[i] } else { 0.0 };
                    assert_eq!(masked[i].to_bits(), expect_mask.to_bits(), "mask n={n} i={i}");
                    let (ek, er) = if keep { (x[i], 0.0) } else { (0.0, x[i]) };
                    assert_eq!(kept[i].to_bits(), ek.to_bits(), "kept n={n} i={i}");
                    assert_eq!(resid[i].to_bits(), er.to_bits(), "resid n={n} i={i}");
                }
            }
        }
    }
}
