//! RandK operator (Stich et al. 2018): keep k uniformly-random coordinates.
//!
//! Used only by the Assumption-1 verification harness (Eq. 20 denominator)
//! and the property tests — never on the training path. The closed-form
//! expectation E[||x - RandK(x,k)||^2] = (1 - k/d)||x||^2 is also provided.

use crate::util::rng::Rng;

/// Dense-masked RandK: k distinct uniformly-chosen coordinates survive.
pub fn randk_mask(x: &[f32], k: usize, rng: &mut Rng) -> Vec<f32> {
    let n = x.len();
    let mut out = vec![0.0f32; n];
    if k >= n {
        out.copy_from_slice(x);
        return out;
    }
    for i in rng.sample_distinct(n, k) {
        out[i] = x[i];
    }
    out
}

/// ||x - RandK(x,k)||^2 for a single draw.
pub fn randk_error_sq(x: &[f32], k: usize, rng: &mut Rng) -> f64 {
    let kept = randk_mask(x, k, rng);
    x.iter().zip(kept.iter()).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
}

/// Closed form E[||x - RandK(x,k)||^2] = (1 - k/d) ||x||^2.
pub fn randk_expected_error_sq(x: &[f32], k: usize) -> f64 {
    let d = x.len();
    if d == 0 {
        return 0.0;
    }
    let norm_sq: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
    (1.0 - (k.min(d) as f64 / d as f64)) * norm_sq
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_exactly_k() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let m = randk_mask(&x, 13, &mut rng);
        assert_eq!(m.iter().filter(|&&v| v != 0.0).count(), 13);
        for (i, &v) in m.iter().enumerate() {
            assert!(v == 0.0 || v == x[i]);
        }
    }

    #[test]
    fn k_geq_n_keeps_all() {
        let mut rng = Rng::new(2);
        let x = vec![1.0f32, 2.0, 3.0];
        assert_eq!(randk_mask(&x, 3, &mut rng), x);
        assert_eq!(randk_mask(&x, 10, &mut rng), x);
    }

    #[test]
    fn monte_carlo_matches_closed_form() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let k = 32;
        let trials = 600;
        let mean: f64 =
            (0..trials).map(|_| randk_error_sq(&x, k, &mut rng)).sum::<f64>() / trials as f64;
        let expect = randk_expected_error_sq(&x, k);
        assert!((mean - expect).abs() / expect < 0.1, "mc={mean} closed={expect}");
    }
}
