//! Gradient sparsification primitives: the host-side (rust) implementations
//! of the Layer-1 kernels, bit-faithful to `python/compile/kernels/ref.py`.
//!
//! * [`topk`] — exact Top-k selection (Eq. 4) via O(n) selection,
//! * [`threshold`] — double-sampling threshold estimation (Lin et al. 2018),
//! * [`randk`] — RandK operator (used by the Assumption-1 harness, Eq. 20),
//! * [`error_feedback`] — per-worker, per-layer residual state (Alg. 1 l.7-8),
//! * [`sparse`] — (index, value) codec for the wire format of sparse
//!   gradient messages.
//!
//! The trainer can run compression either through these host kernels
//! (`CompressorKind::Host*`) or through the AOT Pallas artifacts
//! (`CompressorKind::Xla*`); both produce identical dense-masked results,
//! which `rust/tests/integration_runtime.rs` asserts.
//!
//! Beyond the TopK family, [`compressor`] hosts the zoo behind the
//! [`Compressor`] trait — adaptive-sparsity stochastic compression,
//! global-threshold selection, QSGD-on-TopK quantization, and the
//! `bottom-k` negative control used by `lags validate`'s δ-gate tests
//! (DESIGN.md §Compressor zoo and validation).

pub mod compressor;
pub mod error_feedback;
pub mod randk;
pub mod sparse;
pub mod threshold;
pub mod topk;

pub use compressor::{Compressor, LayerCtx, WireFormat};
pub use error_feedback::ErrorFeedback;
pub use randk::randk_mask;
pub use sparse::SparseVec;
pub use threshold::{sampled_threshold, SampledThreshold};
pub use topk::{kth_largest_abs, topk_mask, topk_mask_into};

/// Which compression implementation the trainer uses for the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressorKind {
    /// Exact Top-k on the host (O(n) select_nth).
    HostExact,
    /// Double-sampling threshold estimate on the host (DGC-style).
    HostSampled,
    /// AOT Pallas compress artifact (exact sort threshold), via PJRT.
    XlaExact,
    /// AOT Pallas compress artifact with strided double-sampling.
    XlaSampled,
    /// Adaptive-sparsity stochastic compression (arxiv 2112.04088).
    AdaptiveStoch,
    /// One global threshold across all layers, per-layer EF (arxiv 2009.09271).
    GlobalTopk,
    /// QSGD stochastic quantizer composed on exact TopK values.
    QsgdTopk,
    /// Negative control: keeps the k SMALLEST magnitudes (δ ≫ 1).
    /// Exists only so the validation gate's failure path stays tested.
    BottomK,
}

impl CompressorKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "host" | "host-exact" => Self::HostExact,
            "host-sampled" => Self::HostSampled,
            "xla" | "xla-exact" => Self::XlaExact,
            "xla-sampled" => Self::XlaSampled,
            "adaptive-stoch" => Self::AdaptiveStoch,
            "global-topk" => Self::GlobalTopk,
            "qsgd-topk" => Self::QsgdTopk,
            "bottom-k" => Self::BottomK,
            _ => anyhow::bail!(
                "unknown compressor {s:?} (host|host-sampled|xla|xla-sampled|\
                 adaptive-stoch|global-topk|qsgd-topk|bottom-k)"
            ),
        })
    }

    /// Canonical spelling accepted back by [`Self::parse`] (config
    /// round-trip).
    pub fn name(self) -> &'static str {
        match self {
            Self::HostExact => "host",
            Self::HostSampled => "host-sampled",
            Self::XlaExact => "xla",
            Self::XlaSampled => "xla-sampled",
            Self::AdaptiveStoch => "adaptive-stoch",
            Self::GlobalTopk => "global-topk",
            Self::QsgdTopk => "qsgd-topk",
            Self::BottomK => "bottom-k",
        }
    }

    pub fn is_xla(self) -> bool {
        matches!(self, Self::XlaExact | Self::XlaSampled)
    }

    /// Instantiate this kind's host-side [`Compressor`]. The `Xla*` kinds
    /// map to their host TopK twins: the device path runs through the AOT
    /// artifacts, but the δ-probe and the trait contract tests still need
    /// a host implementation with identical selection semantics.
    pub fn build(self, sample_stride: usize) -> Box<dyn Compressor> {
        match self {
            Self::HostExact | Self::XlaExact => {
                Box::new(compressor::TopK::new(true, sample_stride))
            }
            Self::HostSampled | Self::XlaSampled => {
                Box::new(compressor::TopK::new(false, sample_stride))
            }
            Self::AdaptiveStoch => Box::new(compressor::AdaptiveStoch),
            Self::GlobalTopk => Box::new(compressor::GlobalTopk::new()),
            Self::QsgdTopk => Box::new(compressor::QsgdTopk::new()),
            Self::BottomK => Box::new(compressor::BottomK::new()),
        }
    }

    /// Bytes-on-wire encoding for this kind (DES + MessageStats pricing).
    pub fn wire(self) -> WireFormat {
        match self {
            Self::QsgdTopk => WireFormat::INDEX_LEVEL,
            _ => WireFormat::INDEX_VALUE,
        }
    }
}
