//! Gradient sparsification primitives: the host-side (rust) implementations
//! of the Layer-1 kernels, bit-faithful to `python/compile/kernels/ref.py`.
//!
//! * [`topk`] — exact Top-k selection (Eq. 4) via O(n) selection,
//! * [`threshold`] — double-sampling threshold estimation (Lin et al. 2018),
//! * [`randk`] — RandK operator (used by the Assumption-1 harness, Eq. 20),
//! * [`error_feedback`] — per-worker, per-layer residual state (Alg. 1 l.7-8),
//! * [`sparse`] — (index, value) codec for the wire format of sparse
//!   gradient messages.
//!
//! The trainer can run compression either through these host kernels
//! (`CompressorKind::Host*`) or through the AOT Pallas artifacts
//! (`CompressorKind::Xla*`); both produce identical dense-masked results,
//! which `rust/tests/integration_runtime.rs` asserts.

pub mod error_feedback;
pub mod randk;
pub mod sparse;
pub mod threshold;
pub mod topk;

pub use error_feedback::ErrorFeedback;
pub use randk::randk_mask;
pub use sparse::SparseVec;
pub use threshold::{sampled_threshold, SampledThreshold};
pub use topk::{kth_largest_abs, topk_mask, topk_mask_into};

/// Which compression implementation the trainer uses for the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressorKind {
    /// Exact Top-k on the host (O(n) select_nth).
    HostExact,
    /// Double-sampling threshold estimate on the host (DGC-style).
    HostSampled,
    /// AOT Pallas compress artifact (exact sort threshold), via PJRT.
    XlaExact,
    /// AOT Pallas compress artifact with strided double-sampling.
    XlaSampled,
}

impl CompressorKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "host" | "host-exact" => Self::HostExact,
            "host-sampled" => Self::HostSampled,
            "xla" | "xla-exact" => Self::XlaExact,
            "xla-sampled" => Self::XlaSampled,
            _ => anyhow::bail!(
                "unknown compressor {s:?} (host|host-sampled|xla|xla-sampled)"
            ),
        })
    }

    /// Canonical spelling accepted back by [`Self::parse`] (config
    /// round-trip).
    pub fn name(self) -> &'static str {
        match self {
            Self::HostExact => "host",
            Self::HostSampled => "host-sampled",
            Self::XlaExact => "xla",
            Self::XlaSampled => "xla-sampled",
        }
    }

    pub fn is_xla(self) -> bool {
        matches!(self, Self::XlaExact | Self::XlaSampled)
    }
}
