//! Exhaustive interleaving enumeration — a miniature, dependency-free
//! `loom` for the repo's message-passing concurrency.
//!
//! The overlap pipeline's only cross-thread interaction is an `mpsc`
//! channel: P producer threads each publish their layers in a fixed
//! per-thread order, and the aggregator consumes whatever interleaving the
//! scheduler produced. Determinism therefore has to hold for **every**
//! merge of the per-thread sequences, not just the handful a live run
//! happens to exercise. This module enumerates exactly that schedule
//! space: all distinct interleavings of `k` sequences with lengths
//! `lens[0..k]`, i.e. the multinomial `(Σ lens)! / Π lens[i]!`, in
//! lexicographic thread-id order (deterministic, so a failing schedule
//! index is a stable repro).
//!
//! `rust/tests/concurrency_model.rs` drives `StreamAggregator`
//! publish/arm_participants/fire ordering and `MergeBuffer`
//! capacity-resize through every schedule and asserts the pipeline
//! invariants (strict backprop-order firing, rank-ordered bit-identical
//! reductions, quorum gating, conservation across resize). What this
//! cannot see — torn reads, reordered non-atomic writes, racy `unsafe` —
//! is covered by the real `loom`/Miri/TSan jobs in the scheduled CI tier
//! (DESIGN.md §Determinism contract and enforcement); what *they* cannot
//! see (loom explores a fixed closure, these tests sweep parameterised
//! topologies) is covered here. The two tiers are complements, not
//! substitutes.

/// Number of distinct interleavings of sequences with the given lengths:
/// `(Σ lens)! / Π (lens[i]!)`, computed without overflow for the sizes the
/// model tests use (panics on u128 overflow otherwise).
pub fn count(lens: &[usize]) -> u128 {
    let mut total: u128 = 1;
    let mut placed: u128 = 0;
    for &len in lens {
        // choose positions for this thread's ops among the slots so far:
        // total *= C(placed + len, len), kept exact by interleaving the
        // multiplications and divisions
        for j in 1..=len as u128 {
            placed += 1;
            total = total.checked_mul(placed).expect("interleaving count overflow") / j;
        }
    }
    total
}

/// Invoke `f` once per distinct interleaving. Each schedule is the full
/// sequence of thread ids, e.g. `[0, 1, 0]` = thread 0's first op, then
/// thread 1's first op, then thread 0's second op. Schedules arrive in
/// lexicographic order of the thread-id sequence. Returns the number of
/// schedules visited.
///
/// Guard rail: panics if the schedule space exceeds `10_000_000` — an
/// exhaustive model that large belongs in the scheduled loom tier, not in
/// `cargo test`.
pub fn for_each_schedule<F: FnMut(&[usize])>(lens: &[usize], mut f: F) -> u128 {
    let total = count(lens);
    assert!(
        total <= 10_000_000,
        "schedule space {total} too large for exhaustive in-test exploration"
    );
    let n: usize = lens.iter().sum();
    if n == 0 {
        f(&[]);
        return 1;
    }
    let mut remaining: Vec<usize> = lens.to_vec();
    let mut schedule: Vec<usize> = Vec::with_capacity(n);
    let mut visited = 0u128;
    dfs(&mut remaining, &mut schedule, n, &mut f, &mut visited);
    debug_assert_eq!(visited, total);
    visited
}

fn dfs<F: FnMut(&[usize])>(
    remaining: &mut [usize],
    schedule: &mut Vec<usize>,
    n: usize,
    f: &mut F,
    visited: &mut u128,
) {
    if schedule.len() == n {
        f(schedule);
        *visited += 1;
        return;
    }
    for t in 0..remaining.len() {
        if remaining[t] == 0 {
            continue;
        }
        remaining[t] -= 1;
        schedule.push(t);
        dfs(remaining, schedule, n, f, visited);
        schedule.pop();
        remaining[t] += 1;
    }
}

/// Convenience: materialise every schedule (small spaces only — the model
/// tests mostly stream via [`for_each_schedule`]).
pub fn schedules(lens: &[usize]) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for_each_schedule(lens, |s| out.push(s.to_vec()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_multinomials() {
        assert_eq!(count(&[]), 1);
        assert_eq!(count(&[3]), 1);
        assert_eq!(count(&[1, 1]), 2);
        assert_eq!(count(&[2, 1]), 3);
        assert_eq!(count(&[2, 2]), 6);
        assert_eq!(count(&[3, 3]), 20);
        // 3 workers x 3 layers: 9! / 6^3
        assert_eq!(count(&[3, 3, 3]), 1680);
        // 2 workers x 4 layers: 8! / (24 * 24)
        assert_eq!(count(&[4, 4]), 70);
    }

    #[test]
    fn enumeration_is_exact_and_lexicographic() {
        let all = schedules(&[2, 1]);
        assert_eq!(all, vec![vec![0, 0, 1], vec![0, 1, 0], vec![1, 0, 0]]);
        let all = schedules(&[1, 1, 1]);
        assert_eq!(all.len(), 6);
        let mut sorted = all.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted, all, "lexicographic and duplicate-free");
    }

    #[test]
    fn each_schedule_preserves_per_thread_order_and_counts() {
        let lens = [3usize, 2, 1];
        let visited = for_each_schedule(&lens, |s| {
            assert_eq!(s.len(), 6);
            for (t, &len) in lens.iter().enumerate() {
                assert_eq!(s.iter().filter(|&&x| x == t).count(), len);
            }
        });
        assert_eq!(visited, count(&lens));
    }

    #[test]
    fn empty_space_has_one_schedule() {
        let mut seen = 0;
        for_each_schedule(&[], |s| {
            assert!(s.is_empty());
            seen += 1;
        });
        assert_eq!(seen, 1);
        assert_eq!(schedules(&[0, 0]).len(), 1);
    }

    #[test]
    #[should_panic(expected = "too large")]
    fn oversized_space_is_rejected() {
        for_each_schedule(&[10, 10, 10], |_| {});
    }
}
