//! `lags-audit` — token/line-level static enforcement of the determinism
//! contract over `rust/src/**` (DESIGN.md §Determinism contract and
//! enforcement).
//!
//! The scanner is deliberately dependency-free and line-oriented: it masks
//! comments, string/char literals and `#[cfg(test)]` blocks with a small
//! carry-over lexer, then matches per-rule token patterns against the
//! remaining code. That is coarse next to a full HIR lint, but it is fast
//! (one pass, no build), runs identically in CI and locally, and the rules
//! it enforces are *textually* recognisable by design — the contract bans
//! whole constructs (`HashMap` in core, `Instant::now` outside the clock
//! funnel), not subtle usages of them.
//!
//! ## Rules
//!
//! * **R1** — no order-unstable collections (`HashMap`/`HashSet`) in the
//!   deterministic core (trainer, cluster, collectives, sparsify,
//!   adaptive, pipeline, runtime::native/kernels, util::rng): iteration
//!   order would leak into reductions, telemetry and checkpoints.
//! * **R2** — no wall-clock or environment reads (`Instant::now`,
//!   `SystemTime`, `std::env`) anywhere except the single clock funnel
//!   `util::clock::now` (structurally whitelisted).
//! * **R3** — no float accumulation (`.fold(`, `.sum::<f32>`,
//!   `.sum::<f64>`) in core modules outside the fixed-order sites
//!   `runtime::kernels` and `collectives::sparse_agg`.
//! * **R4** — `unsafe` denied crate-wide (backed by
//!   `#![deny(unsafe_code)]`) and confined to `runtime::simd`, the
//!   explicit SIMD kernel tier: every `unsafe` token there must carry an
//!   individually reasoned waiver, and any bare `unsafe` anywhere else is
//!   a hard finding.
//! * **R5** — no randomness source other than `util::rng::Rng` (no
//!   `rand::`, `thread_rng`, `getrandom`, `RandomState`, `chrono::`),
//!   and no hand-rolled generators either: the multiplier/gamma
//!   constants of xorshift64*, splitmix64, the MMIX LCG/PCG and wyrand
//!   are fingerprints — stochastic code (e.g. compressors) must draw
//!   from `util::rng`'s forked streams, never a private PRNG.
//! * **W0** — waiver-protocol violations (a waiver that lacks a
//!   `reason="..."`, names an unknown rule, or cannot be parsed). W0 is
//!   not waivable.
//!
//! ## Waivers
//!
//! A finding is suppressed — but still reported in `audit.json` — by an
//! inline comment on the same line, or on a comment-only line directly
//! above: `// lags-audit: allow(R1) reason="membership-only set, never
//! iterated"`. A waiver without a reason does not suppress anything and is
//! itself a W0 finding, so exceptions are always visible and always
//! justified. Waivers that match no finding are ignored (this lets docs —
//! like this one — quote the syntax without tripping the scanner).

use crate::util::json::{self, Json};
use crate::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A determinism-contract rule (or the waiver meta-rule `W0`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    /// Waiver-protocol violation (missing reason / unknown rule id).
    W0,
}

impl Rule {
    /// The scannable rules, in report order (W0 findings are synthesized
    /// by the waiver machinery, never pattern-matched).
    pub const CHECKS: [Rule; 5] = [Rule::R1, Rule::R2, Rule::R3, Rule::R4, Rule::R5];

    pub fn id(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::W0 => "W0",
        }
    }

    pub fn summary(self) -> &'static str {
        match self {
            Rule::R1 => "no order-unstable collections (HashMap/HashSet) in deterministic core",
            Rule::R2 => "no wall-clock or environment reads outside util::clock::now",
            Rule::R3 => "no float accumulation outside runtime::kernels / collectives::sparse_agg",
            Rule::R4 => "unsafe denied crate-wide; confined to runtime::simd under reasoned waivers",
            Rule::R5 => "no randomness source other than util::rng::Rng (incl. hand-rolled PRNGs)",
            Rule::W0 => "waiver protocol: waivers must parse, name known rules, and carry a reason",
        }
    }

    /// Parse a rule id as it appears inside `allow(...)`. `W0` is not
    /// waivable, so it does not parse.
    pub fn parse(s: &str) -> Option<Rule> {
        match s {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            _ => None,
        }
    }

    fn patterns(self) -> &'static [&'static str] {
        match self {
            Rule::R1 => &["HashMap", "HashSet"],
            Rule::R2 => &["Instant::now", "SystemTime", "std::env"],
            Rule::R3 => &[".fold(", ".sum::<f32>", ".sum::<f64>"],
            Rule::R4 => &["unsafe"],
            Rule::R5 => &[
                "rand::",
                "thread_rng",
                "from_entropy",
                "getrandom",
                "RandomState",
                "chrono::",
                // hand-rolled PRNG fingerprints (both hex cases; the
                // token-boundary check keeps suffixed lookalikes out):
                // xorshift64* multiplier
                "0x2545F4914F6CDD1D",
                "0x2545f4914f6cdd1d",
                // splitmix64 golden gamma (util/rng.rs is the one funnel)
                "0x9E3779B97F4A7C15",
                "0x9e3779b97f4a7c15",
                // MMIX LCG / PCG default multiplier
                "6364136223846793005",
                // wyrand increment
                "0xA0761D6478BD642F",
                "0xa0761d6478bd642f",
            ],
            Rule::W0 => &[],
        }
    }

    /// Does this rule apply to the file at (root-relative, '/'-separated)
    /// path `rel`?
    fn applies(self, rel: &str) -> bool {
        match self {
            Rule::R1 => is_core(rel),
            Rule::R2 => rel != "util/clock.rs",
            Rule::R3 => {
                is_core(rel) && rel != "runtime/kernels.rs" && rel != "collectives/sparse_agg.rs"
            }
            Rule::R4 => true,
            Rule::R5 => rel != "util/rng.rs",
            Rule::W0 => true,
        }
    }
}

/// Deterministic-core membership: modules whose state feeds the
/// bit-identity contract (params, residuals, message stats, checkpoints).
fn is_core(rel: &str) -> bool {
    const CORE_PREFIXES: [&str; 6] =
        ["trainer/", "cluster/", "collectives/", "sparsify/", "adaptive/", "pipeline/"];
    const CORE_FILES: [&str; 4] =
        ["runtime/native.rs", "runtime/kernels.rs", "runtime/simd.rs", "util/rng.rs"];
    CORE_PREFIXES.iter().any(|p| rel.starts_with(p)) || CORE_FILES.contains(&rel)
}

/// One audit hit: a rule match (waived or not) or a W0 protocol violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    /// path relative to the scan root, '/'-separated
    pub file: String,
    /// 1-based line number
    pub line: usize,
    /// the matched pattern, or a description of the protocol violation
    pub what: String,
    /// the offending source line, trimmed
    pub excerpt: String,
    /// `Some(reason)` when suppressed by a valid waiver
    pub waiver: Option<String>,
}

impl Finding {
    pub fn is_waived(&self) -> bool {
        self.waiver.is_some()
    }
}

/// The result of auditing a tree (or a single in-memory source).
#[derive(Debug, Default)]
pub struct AuditReport {
    pub root: String,
    pub files_scanned: usize,
    /// every finding, waived and unwaived, sorted by (file, line, rule)
    pub findings: Vec<Finding>,
}

impl AuditReport {
    pub fn unwaived(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| !f.is_waived()).collect()
    }

    pub fn waivers(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| f.is_waived()).collect()
    }

    /// Zero unwaived findings?
    pub fn clean(&self) -> bool {
        self.findings.iter().all(|f| f.is_waived())
    }

    /// The machine-readable `audit.json` payload: rule table, unwaived
    /// findings, and every effective waiver (exceptions are visible, never
    /// silent).
    pub fn to_json(&self) -> Json {
        let rule_row = |r: Rule| {
            Json::obj(vec![
                ("id", Json::Str(r.id().to_string())),
                ("summary", Json::Str(r.summary().to_string())),
            ])
        };
        let finding_row = |f: &Finding| {
            let mut pairs = vec![
                ("rule", Json::Str(f.rule.id().to_string())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("what", Json::Str(f.what.clone())),
                ("excerpt", Json::Str(f.excerpt.clone())),
            ];
            if let Some(r) = &f.waiver {
                pairs.push(("reason", Json::Str(r.clone())));
            }
            Json::obj(pairs)
        };
        Json::obj(vec![
            ("root", Json::Str(self.root.clone())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            (
                "rules",
                Json::Arr(Rule::CHECKS.iter().chain([&Rule::W0]).map(|&r| rule_row(r)).collect()),
            ),
            (
                "findings",
                Json::Arr(self.unwaived().into_iter().map(finding_row).collect()),
            ),
            ("waivers", Json::Arr(self.waivers().into_iter().map(finding_row).collect())),
            ("clean", Json::Bool(self.clean())),
        ])
    }

    /// Human-readable summary for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let unwaived = self.unwaived();
        let waived = self.waivers();
        for f in &unwaived {
            out.push_str(&format!(
                "{} {}:{} [{}] {}\n    {}\n",
                f.rule.id(),
                f.file,
                f.line,
                f.what,
                f.rule.summary(),
                f.excerpt
            ));
        }
        if !waived.is_empty() {
            out.push_str("waivers in effect:\n");
            for f in &waived {
                out.push_str(&format!(
                    "  {} {}:{} [{}] reason: {}\n",
                    f.rule.id(),
                    f.file,
                    f.line,
                    f.what,
                    f.waiver.as_deref().unwrap_or("")
                ));
            }
        }
        out.push_str(&format!(
            "lags-audit: {} file(s), {} finding(s), {} waived, {} unwaived\n",
            self.files_scanned,
            self.findings.len(),
            waived.len(),
            unwaived.len()
        ));
        out
    }
}

// ---------------------------------------------------------------------------
// lexer: mask comments / string / char literals so patterns only see code
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LexState {
    Code,
    /// inside `/* ... */`, with nesting depth
    Block(u32),
    /// inside a `"..."` string literal
    Str,
    /// inside a raw string, with the `#` count of its delimiter
    RawStr(u8),
}

/// Per-file masking lexer; state carries across lines (block comments and
/// string literals may span lines).
struct Masker {
    state: LexState,
}

impl Masker {
    fn new() -> Masker {
        Masker { state: LexState::Code }
    }

    /// Replace comment and literal interiors with spaces, preserving code
    /// tokens and braces. Line comments truncate the line.
    fn mask_line(&mut self, raw: &str) -> String {
        let c: Vec<char> = raw.chars().collect();
        let n = c.len();
        let mut out = String::with_capacity(n);
        let mut i = 0usize;
        while i < n {
            match self.state {
                LexState::Block(depth) => {
                    if c[i] == '*' && i + 1 < n && c[i + 1] == '/' {
                        self.state =
                            if depth <= 1 { LexState::Code } else { LexState::Block(depth - 1) };
                        out.push_str("  ");
                        i += 2;
                    } else if c[i] == '/' && i + 1 < n && c[i + 1] == '*' {
                        self.state = LexState::Block(depth + 1);
                        out.push_str("  ");
                        i += 2;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
                LexState::Str => {
                    if c[i] == '\\' {
                        out.push(' ');
                        if i + 1 < n {
                            out.push(' ');
                        }
                        i = (i + 2).min(n);
                    } else if c[i] == '"' {
                        self.state = LexState::Code;
                        out.push(' ');
                        i += 1;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
                LexState::RawStr(h) => {
                    if c[i] == '"' && (1..=h as usize).all(|k| c.get(i + k) == Some(&'#')) {
                        self.state = LexState::Code;
                        for _ in 0..=h as usize {
                            out.push(' ');
                        }
                        i += 1 + h as usize;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
                LexState::Code => {
                    let ch = c[i];
                    if ch == '/' && i + 1 < n && c[i + 1] == '/' {
                        break; // line comment: rest of line is not code
                    }
                    if ch == '/' && i + 1 < n && c[i + 1] == '*' {
                        self.state = LexState::Block(1);
                        out.push_str("  ");
                        i += 2;
                        continue;
                    }
                    if ch == '"' {
                        self.state = LexState::Str;
                        out.push(' ');
                        i += 1;
                        continue;
                    }
                    if ch == 'r' && !ends_in_ident(&out) {
                        // raw string r"..." / r#"..."#
                        let mut j = i + 1;
                        let mut hashes = 0u8;
                        while j < n && c[j] == '#' && hashes < u8::MAX {
                            hashes += 1;
                            j += 1;
                        }
                        if j < n && c[j] == '"' {
                            self.state = LexState::RawStr(hashes);
                            for _ in i..=j {
                                out.push(' ');
                            }
                            i = j + 1;
                            continue;
                        }
                        out.push(ch);
                        i += 1;
                        continue;
                    }
                    if ch == '\'' {
                        // char literal vs lifetime
                        if i + 1 < n && c[i + 1] == '\\' {
                            let mut j = i + 2;
                            while j < n && c[j] != '\'' && j < i + 12 {
                                j += 1;
                            }
                            let end = j.min(n.saturating_sub(1));
                            for _ in i..=end {
                                out.push(' ');
                            }
                            i = j + 1;
                            continue;
                        }
                        if i + 2 < n && c[i + 2] == '\'' && c[i + 1] != '\'' {
                            out.push_str("   ");
                            i += 3;
                            continue;
                        }
                        out.push('\'');
                        i += 1;
                        continue;
                    }
                    out.push(ch);
                    i += 1;
                }
            }
        }
        out
    }
}

fn ends_in_ident(s: &str) -> bool {
    s.chars().next_back().map(|c| c.is_alphanumeric() || c == '_').unwrap_or(false)
}

/// Substring search with identifier-boundary checks on pattern edges that
/// are themselves identifier characters (so `unsafe` does not match
/// `unsafe_code`, and `HashMap` does not match `MyHashMapLike`).
fn has_token(hay: &str, pat: &str) -> bool {
    let first = pat.chars().next().unwrap();
    let last = pat.chars().next_back().unwrap();
    let need_before = first.is_alphanumeric() || first == '_';
    let need_after = last.is_alphanumeric() || last == '_';
    let mut start = 0usize;
    while let Some(pos) = hay[start..].find(pat) {
        let p = start + pos;
        let end = p + pat.len();
        let before_ok = !need_before || !ends_in_ident(&hay[..p]);
        let after_ok = !need_after
            || hay[end..].chars().next().map(|c| !(c.is_alphanumeric() || c == '_')).unwrap_or(true);
        if before_ok && after_ok {
            return true;
        }
        start = end;
    }
    false
}

// ---------------------------------------------------------------------------
// waivers
// ---------------------------------------------------------------------------

const WAIVER_MARK: &str = concat!("lags-", "audit:");

#[derive(Debug)]
struct Waiver {
    rules: Vec<Rule>,
    reason: Option<String>,
    /// 0-based line the waiver comment sits on
    line: usize,
    /// 0-based line the waiver suppresses findings on
    target: usize,
    /// set when the waiver matched a finding but had no reason
    reason_missing_hit: bool,
}

enum WaiverParse {
    Ok { rules: Vec<Rule>, reason: Option<String> },
    Malformed(String),
    NotAWaiver,
}

/// Parse a waiver from a raw source line. Only text that follows the
/// marker with `allow(` is treated as a waiver attempt; anything else
/// (docs quoting the marker) is ignored.
fn parse_waiver(raw: &str) -> WaiverParse {
    let Some(pos) = raw.find(WAIVER_MARK) else {
        return WaiverParse::NotAWaiver;
    };
    let rest = raw[pos + WAIVER_MARK.len()..].trim_start();
    let Some(rest) = rest.strip_prefix("allow") else {
        return WaiverParse::NotAWaiver;
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return WaiverParse::Malformed("allow not followed by (rule list)".to_string());
    };
    let Some(close) = rest.find(')') else {
        return WaiverParse::Malformed("unterminated allow(...)".to_string());
    };
    let mut rules = Vec::new();
    for name in rest[..close].split(',') {
        let name = name.trim();
        match Rule::parse(name) {
            Some(r) => rules.push(r),
            None => {
                return WaiverParse::Malformed(format!("unknown rule id {name:?} in allow(...)"))
            }
        }
    }
    if rules.is_empty() {
        return WaiverParse::Malformed("empty rule list in allow(...)".to_string());
    }
    let tail = &rest[close + 1..];
    let reason = tail.find("reason=\"").and_then(|r| {
        let s = &tail[r + 8..];
        s.find('"').map(|e| s[..e].to_string())
    });
    let reason = reason.filter(|r| !r.trim().is_empty());
    WaiverParse::Ok { rules, reason }
}

// ---------------------------------------------------------------------------
// scanning
// ---------------------------------------------------------------------------

fn brace_delta(masked: &str) -> (usize, usize) {
    let opens = masked.chars().filter(|&c| c == '{').count();
    let closes = masked.chars().filter(|&c| c == '}').count();
    (opens, closes)
}

fn excerpt_of(raw: &str) -> String {
    let t = raw.trim();
    if t.len() > 120 {
        let mut cut = 120;
        while !t.is_char_boundary(cut) {
            cut -= 1;
        }
        format!("{}…", &t[..cut])
    } else {
        t.to_string()
    }
}

/// Audit a single source file (given as text). `rel` is the path relative
/// to the scan root with '/' separators — it selects which rules apply.
/// `#[cfg(test)]` items/blocks are skipped: test code is exercised by the
/// dynamic tier, and clippy's `disallowed-*` lists cover it under
/// `--all-targets`.
pub fn audit_source(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mut masker = Masker::new();
    let mut masked: Vec<String> = Vec::with_capacity(lines.len());
    let mut scanned = vec![false; lines.len()];
    let mut pending_attr = false;
    let mut skip_depth: Option<usize> = None;

    for (i, raw) in lines.iter().enumerate() {
        let m = masker.mask_line(raw);
        if let Some(d) = skip_depth {
            let (o, c) = brace_delta(&m);
            let nd = (d + o).saturating_sub(c);
            skip_depth = if nd == 0 { None } else { Some(nd) };
            masked.push(m);
            continue;
        }
        let has_code = !m.trim().is_empty();
        if pending_attr {
            if !has_code {
                masked.push(m);
                continue; // blank/comment line between attribute and item
            }
            if m.trim_start().starts_with("#[") && !m.contains("cfg(test)") {
                masked.push(m);
                continue; // stacked attribute; keep waiting for the item
            }
            let (o, c) = brace_delta(&m);
            if o > c {
                skip_depth = Some(o - c);
            }
            pending_attr = false;
            masked.push(m);
            continue; // the cfg(test) item line itself is not scanned
        }
        if m.contains("#[cfg(test)]") {
            let (o, c) = brace_delta(&m);
            if o > c {
                skip_depth = Some(o - c);
            } else {
                pending_attr = true;
            }
            masked.push(m);
            continue;
        }
        scanned[i] = true;
        masked.push(m);
    }

    // collect waivers on scanned lines; comment-only waivers target the
    // next scanned line that has code
    let mut findings: Vec<Finding> = Vec::new();
    let mut waivers: Vec<Waiver> = Vec::new();
    for i in 0..lines.len() {
        if !scanned[i] {
            continue;
        }
        match parse_waiver(lines[i]) {
            WaiverParse::NotAWaiver => {}
            WaiverParse::Malformed(msg) => findings.push(Finding {
                rule: Rule::W0,
                file: rel.to_string(),
                line: i + 1,
                what: msg,
                excerpt: excerpt_of(lines[i]),
                waiver: None,
            }),
            WaiverParse::Ok { rules, reason } => {
                let target = if !masked[i].trim().is_empty() {
                    Some(i)
                } else {
                    (i + 1..lines.len()).find(|&j| scanned[j] && !masked[j].trim().is_empty())
                };
                if let Some(target) = target {
                    waivers.push(Waiver { rules, reason, line: i, target, reason_missing_hit: false });
                }
            }
        }
    }

    // pattern scan
    for i in 0..lines.len() {
        if !scanned[i] || masked[i].trim().is_empty() {
            continue;
        }
        for rule in Rule::CHECKS {
            if !rule.applies(rel) {
                continue;
            }
            for pat in rule.patterns() {
                if !has_token(&masked[i], pat) {
                    continue;
                }
                let mut reason: Option<String> = None;
                for w in waivers.iter_mut() {
                    if w.target == i && w.rules.contains(&rule) {
                        match &w.reason {
                            Some(r) => reason = Some(r.clone()),
                            // reasonless waiver: the finding stays unwaived
                            // and the waiver becomes a W0 below
                            None => w.reason_missing_hit = true,
                        }
                        break;
                    }
                }
                findings.push(Finding {
                    rule,
                    file: rel.to_string(),
                    line: i + 1,
                    what: (*pat).to_string(),
                    excerpt: excerpt_of(lines[i]),
                    waiver: reason,
                });
            }
        }
    }

    // waivers that matched a finding but carried no reason are protocol
    // violations in their own right
    for w in &waivers {
        if w.reason_missing_hit {
            findings.push(Finding {
                rule: Rule::W0,
                file: rel.to_string(),
                line: w.line + 1,
                what: "waiver suppresses nothing: missing reason=\"...\"".to_string(),
                excerpt: excerpt_of(lines[w.line]),
                waiver: None,
            });
        }
    }

    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Recursively audit every `.rs` file under `root` (deterministic,
/// lexicographic walk). `root` is typically `rust/src`.
pub fn audit_tree(root: &Path) -> Result<AuditReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)
        .with_context(|| format!("walking audit root {}", root.display()))?;
    files.sort();
    let mut report = AuditReport {
        root: root.display().to_string(),
        files_scanned: 0,
        findings: Vec::new(),
    };
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        report.findings.extend(audit_source(&rel, &text));
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Shared driver for `lags audit` and the standalone `lags-audit` bin:
/// audit `root`, print the report, write `audit.json` to `json_out`, and
/// fail (non-zero exit through the caller's error path) on any unwaived
/// finding.
pub fn run_cli(root: &Path, json_out: Option<&Path>) -> Result<()> {
    if !root.is_dir() {
        bail!("audit root {} is not a directory (pass --root <dir>)", root.display());
    }
    let report = audit_tree(root)?;
    print!("{}", report.render());
    if let Some(path) = json_out {
        json::write_atomic(path, report.to_json().to_string_pretty().as_bytes())?;
        println!("wrote {}", path.display());
    }
    if !report.clean() {
        bail!("lags-audit: {} unwaived finding(s)", report.unwaived().len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_all(src: &str) -> Vec<String> {
        let mut m = Masker::new();
        src.lines().map(|l| m.mask_line(l)).collect()
    }

    #[test]
    fn masker_strips_comments_and_strings() {
        let m = mask_all("let x = \"HashMap\"; // HashMap\nlet y = 1; /* unsafe */ let z = 2;");
        assert!(!m[0].contains("HashMap"));
        assert!(m[0].contains("let x ="));
        assert!(!m[1].contains("unsafe"));
        assert!(m[1].contains("let z = 2;"));
    }

    #[test]
    fn masker_handles_multiline_block_and_raw_strings() {
        let m = mask_all("let a = 1; /* start\nstill unsafe here\nend */ let b = 2;");
        assert!(m[0].contains("let a = 1;"));
        assert!(!m[1].contains("unsafe"));
        assert!(m[2].contains("let b = 2;"));
        let m = mask_all("let s = r#\"Instant::now\"#; let t = 3;");
        assert!(!m[0].contains("Instant::now"));
        assert!(m[0].contains("let t = 3;"));
    }

    #[test]
    fn masker_distinguishes_char_literal_from_lifetime() {
        let m = mask_all("fn f<'a>(x: &'a str) { let c = '{'; let d = '\\n'; }");
        // lifetime survives, char literals (including brace) are masked
        assert!(m[0].contains("<'a>"));
        assert_eq!(m[0].chars().filter(|&c| c == '{').count(), 1);
        let (o, c) = brace_delta(&m[0]);
        assert_eq!((o, c), (1, 1));
    }

    #[test]
    fn token_boundaries_respected() {
        assert!(has_token("unsafe { }", "unsafe"));
        assert!(!has_token("#![forbid(unsafe_code)]", "unsafe"));
        assert!(has_token("let m: HashMap<u32, u32>;", "HashMap"));
        assert!(!has_token("struct MyHashMapLike;", "HashMap"));
        assert!(has_token("std::env::args()", "std::env"));
    }

    #[test]
    fn r1_fires_in_core_only() {
        let src = "use std::collections::HashMap;\n";
        let core = audit_source("trainer/mod.rs", src);
        assert_eq!(core.len(), 1);
        assert_eq!(core[0].rule, Rule::R1);
        assert_eq!(core[0].line, 1);
        assert!(audit_source("metrics/mod.rs", src).is_empty());
    }

    #[test]
    fn r2_fires_everywhere_but_clock() {
        let src = "let t = std::time::Instant::now();\n";
        assert_eq!(audit_source("metrics/mod.rs", src).len(), 1);
        assert_eq!(audit_source("trainer/mod.rs", src).len(), 1);
        assert!(audit_source("util/clock.rs", src).is_empty());
    }

    #[test]
    fn r3_allows_fixed_order_sites() {
        let src = "let s = xs.iter().sum::<f32>();\n";
        assert_eq!(audit_source("collectives/pipeline.rs", src).len(), 1);
        assert!(audit_source("collectives/sparse_agg.rs", src).is_empty());
        assert!(audit_source("runtime/kernels.rs", src).is_empty());
        assert!(audit_source("metrics/mod.rs", src).is_empty(), "R3 is core-scoped");
    }

    #[test]
    fn waiver_suppresses_and_reports() {
        let src = format!(
            "let t = Instant::now(); // {} allow(R2) reason=\"test fixture\"\n",
            WAIVER_MARK
        );
        let fs = audit_source("trainer/mod.rs", &src);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].is_waived());
        assert_eq!(fs[0].waiver.as_deref(), Some("test fixture"));
    }

    #[test]
    fn preceding_line_waiver_targets_next_code_line() {
        let src = format!(
            "// {} allow(R1) reason=\"point lookups only\"\nlet m = HashMap::new();\n",
            WAIVER_MARK
        );
        let fs = audit_source("cluster/mod.rs", &src);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].is_waived());
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn reasonless_waiver_is_a_w0_and_suppresses_nothing() {
        let src = format!("let t = Instant::now(); // {} allow(R2)\n", WAIVER_MARK);
        let fs = audit_source("trainer/mod.rs", &src);
        assert_eq!(fs.len(), 2);
        assert!(fs.iter().any(|f| f.rule == Rule::R2 && !f.is_waived()));
        assert!(fs.iter().any(|f| f.rule == Rule::W0));
    }

    #[test]
    fn unknown_rule_in_waiver_is_malformed() {
        let src = format!("// {} allow(R9) reason=\"x\"\nlet y = 1;\n", WAIVER_MARK);
        let fs = audit_source("trainer/mod.rs", &src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::W0);
    }

    #[test]
    fn unused_waiver_is_ignored() {
        let src = format!("// {} allow(R2) reason=\"docs example\"\nlet y = 1;\n", WAIVER_MARK);
        assert!(audit_source("trainer/mod.rs", &src).is_empty());
    }

    #[test]
    fn cfg_test_blocks_are_skipped() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn g() { let t = std::time::Instant::now(); }\n}\nfn h() { let m: HashMap<u8, u8> = HashMap::new(); }\n";
        let fs = audit_source("trainer/mod.rs", src);
        // only the HashMap *outside* the test mod fires
        assert!(!fs.is_empty());
        assert!(fs.iter().all(|f| f.line == 7 && f.rule == Rule::R1));
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "// uses Instant::now and HashMap\nlet s = \"unsafe HashMap Instant::now\";\n";
        assert!(audit_source("trainer/mod.rs", src).is_empty());
    }

    #[test]
    fn r4_and_r5_fire_crate_wide() {
        let fs = audit_source("metrics/mod.rs", "unsafe { core::hint::unreachable_unchecked() }\n");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::R4);
        let fs = audit_source("util/json.rs", "let r = rand::thread_rng();\n");
        assert_eq!(fs.iter().filter(|f| f.rule == Rule::R5).count(), 2);
    }

    #[test]
    fn report_json_shape() {
        let findings = audit_source(
            "trainer/mod.rs",
            &format!(
                "let m = HashMap::new(); // {} allow(R1) reason=\"fixture\"\nunsafe {{}}\n",
                WAIVER_MARK
            ),
        );
        let rep = AuditReport { root: "mem".to_string(), files_scanned: 1, findings };
        let j = rep.to_json();
        assert!(!j.get("clean").unwrap().as_bool().unwrap());
        assert_eq!(j.get("findings").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("waivers").unwrap().as_arr().unwrap().len(), 1);
        let w = &j.get("waivers").unwrap().as_arr().unwrap()[0];
        assert_eq!(w.get("reason").unwrap().as_str().unwrap(), "fixture");
        // render is total
        assert!(rep.render().contains("unwaived"));
    }
}
