//! Static + model-based enforcement of the determinism contract.
//!
//! The repo's central guarantee — same seed + same fault plan ⇒
//! bit-identical losses, params, and message stats across thread counts,
//! pipeline modes, elastic membership, and kill-and-resume — used to be
//! enforced only *dynamically*, by the integration-test matrices. This
//! module makes the contract statically checkable and adds a deterministic
//! concurrency-model harness for the parts a lint cannot see:
//!
//! * [`audit`] — `lags-audit`, a dependency-free token/line-level scanner
//!   over `rust/src/**` that enforces rules R1–R5 (order-unstable
//!   collections, wall-clock/env reads, unordered float accumulation,
//!   `unsafe`, non-`util::rng` randomness) with an explicit, machine-
//!   readable waiver protocol (`audit.json`). Run via `lags audit` or the
//!   standalone `lags-audit` bin; gates the fast CI tier.
//! * [`interleave`] — an exhaustive interleaving enumerator (a miniature,
//!   dependency-free loom): tests replay every legal schedule of
//!   concurrent producer operations against `StreamAggregator` /
//!   `MergeBuffer` invariants, so "determinism survives the overlap" is
//!   checked over the *whole* schedule space, not the few orders a live
//!   `mpsc` race happens to produce. The real `loom`/Miri/TSan jobs in the
//!   scheduled CI tier cover the memory-model layer below this
//!   (DESIGN.md §Determinism contract and enforcement).

//! * [`validate`] — `lags validate`, the Assumption-1 convergence gate:
//!   runs the (zoo model × compressor) matrix, records per-layer δ^(l)
//!   with the ACTUAL compressor in the numerator, and emits the
//!   `validation.json` artifact CI fails on when δ > 1 + tol.

pub mod audit;
pub mod interleave;
pub mod validate;

pub use audit::{audit_tree, AuditReport, Finding, Rule};
pub use validate::{ValidateSpec, ValidationReport};
