//! `lags validate` — the Assumption-1 convergence-validation harness.
//!
//! Runs a matrix of (zoo model × compressor) short training jobs, records
//! the per-layer δ^(l) series (Eq. 20) with the ACTUAL compressor's
//! compression error in the numerator (via the generalized
//! [`crate::metrics::delta_metric_with`]), and gates on δ^(l) ≤ 1 + tol
//! at every sampled step. The emitted `validation.json` is the artifact
//! the fast CI tier parses and fails on.
//!
//! Tolerance rationale: Assumption 1 compares the compressor's error to
//! the EXPECTED RandK error. A compressor can sit epsilon above 1 without
//! breaking the §4 convergence argument in practice — e.g. `global-topk`
//! starves a layer whose coordinates all fall below the model-wide
//! threshold, giving δ = 1/(1 − k/n) ≈ 1.01 at c = 100 — while a genuine
//! Assumption-1 violator (the `bottom-k` negative control at c = 2) lands
//! at δ ≈ 2. `DELTA_TOL` = 0.15 separates those regimes with wide margin
//! on both sides.

use crate::config::TrainConfig;
use crate::metrics::delta_to_json;
use crate::runtime::Runtime;
use crate::sparsify::CompressorKind;
use crate::trainer::{Algorithm, Trainer};
use crate::util::json::Json;
use anyhow::Result;
use std::sync::Arc;

/// Bumped whenever the validation.json shape changes; CI greps for it.
pub const SCHEMA_VERSION: usize = 1;

/// The δ ≤ 1 + DELTA_TOL acceptance band (module docs for the rationale).
pub const DELTA_TOL: f64 = 0.15;

/// The compressors every validation tier must clear — the shipped zoo
/// (host paths only: XLA compressors share the host TopK semantics and
/// need a PJRT device, so they are exercised by the runtime tests
/// instead).
pub const ZOO: [CompressorKind; 5] = [
    CompressorKind::HostExact,
    CompressorKind::HostSampled,
    CompressorKind::AdaptiveStoch,
    CompressorKind::GlobalTopk,
    CompressorKind::QsgdTopk,
];

/// One validation matrix: which models × compressors, for how long.
#[derive(Debug, Clone)]
pub struct ValidateSpec {
    pub models: Vec<String>,
    pub compressors: Vec<CompressorKind>,
    pub steps: usize,
    pub workers: usize,
    /// δ sampling cadence (steps)
    pub delta_every: usize,
    pub tolerance: f64,
    pub seed: u64,
    /// "quick" | "full" — recorded in validation.json
    pub mode: String,
    /// append the `bottom-k` negative-control leg (c = 2, keeps the
    /// SMALLEST coordinates): the run must then FAIL the δ gate — CI's
    /// check that the gate actually has teeth
    pub inject_violation: bool,
}

impl ValidateSpec {
    /// The PR-tier matrix: the two cheap models × the full zoo.
    pub fn quick(seed: u64) -> ValidateSpec {
        ValidateSpec {
            models: vec!["mlp".into(), "convnet".into()],
            compressors: ZOO.to_vec(),
            steps: 30,
            workers: 4,
            delta_every: 5,
            tolerance: DELTA_TOL,
            seed,
            mode: "quick".into(),
            inject_violation: false,
        }
    }

    /// The scheduled-tier matrix: every native zoo model × the full zoo.
    pub fn full(seed: u64) -> ValidateSpec {
        ValidateSpec {
            models: vec![
                "mlp".into(),
                "mlp_deep".into(),
                "convnet".into(),
                "convnet_deep".into(),
                "rnn".into(),
            ],
            steps: 60,
            mode: "full".into(),
            ..ValidateSpec::quick(seed)
        }
    }
}

/// Per-layer δ statistics over one leg's sampled series.
#[derive(Debug, Clone)]
pub struct LayerDelta {
    pub layer: String,
    /// max and mean can be `f64::INFINITY` for a degenerate sample
    /// (den == 0 with a nonzero numerator) — serialized via the tagged
    /// sentinel, never as a bare IEEE special
    pub max_delta: f64,
    pub mean_delta: f64,
    pub samples: usize,
    /// steps where δ > 1 + tolerance
    pub violations: Vec<usize>,
}

impl LayerDelta {
    fn from_series(layer: &str, series: &[(usize, f64)], tolerance: f64) -> LayerDelta {
        let mut max_delta = 0.0f64;
        let mut sum = 0.0f64;
        let mut violations = Vec::new();
        for &(step, d) in series {
            max_delta = max_delta.max(d);
            sum += d;
            // NaN/inf-robust: a degenerate sample is never "holding"
            if !(d <= 1.0 + tolerance) {
                violations.push(step);
            }
        }
        let mean_delta = if series.is_empty() { 0.0 } else { sum / series.len() as f64 };
        let layer = layer.to_string();
        LayerDelta { layer, max_delta, mean_delta, samples: series.len(), violations }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layer", Json::Str(self.layer.clone())),
            ("max_delta", delta_to_json(self.max_delta)),
            ("mean_delta", delta_to_json(self.mean_delta)),
            ("samples", Json::Num(self.samples as f64)),
            (
                "violations",
                Json::Arr(self.violations.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
        ])
    }
}

/// One (model × compressor) leg of the matrix.
#[derive(Debug, Clone)]
pub struct LegResult {
    pub model: String,
    pub compressor: String,
    pub final_loss: f64,
    /// the dense same-seed same-budget baseline's final loss
    pub dense_final_loss: f64,
    /// final_loss − dense_final_loss (positive = sparsification cost)
    pub loss_gap: f64,
    /// fraction of δ samples ≤ 1 exactly (the monitor's strict count;
    /// the gate itself uses the tolerance band)
    pub delta_fraction_holding: Option<f64>,
    pub layers: Vec<LayerDelta>,
    pub pass: bool,
}

impl LegResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("compressor", Json::Str(self.compressor.clone())),
            ("final_loss", Json::Num(self.final_loss)),
            ("dense_final_loss", Json::Num(self.dense_final_loss)),
            ("loss_gap", Json::Num(self.loss_gap)),
            (
                "delta_fraction_holding",
                self.delta_fraction_holding.map(delta_to_json).unwrap_or(Json::Null),
            ),
            ("layers", Json::Arr(self.layers.iter().map(LayerDelta::to_json).collect())),
            ("pass", Json::Bool(self.pass)),
        ])
    }

    pub fn summary_line(&self) -> String {
        let max = self.layers.iter().map(|l| l.max_delta).fold(0.0f64, f64::max);
        let violations: usize = self.layers.iter().map(|l| l.violations.len()).sum();
        format!(
            "validate {:<13} {:<14} max_delta={:.4} violations={} loss_gap={:+.4} {}",
            self.model,
            self.compressor,
            max,
            violations,
            self.loss_gap,
            if self.pass { "PASS" } else { "FAIL" }
        )
    }
}

/// The whole matrix's outcome — what `validation.json` serializes.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub mode: String,
    pub tolerance: f64,
    pub results: Vec<LegResult>,
    pub pass: bool,
}

impl ValidationReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("mode", Json::Str(self.mode.clone())),
            ("tolerance", Json::Num(self.tolerance)),
            ("results", Json::Arr(self.results.iter().map(LegResult::to_json).collect())),
            ("pass", Json::Bool(self.pass)),
        ])
    }
}

/// The training config of one leg. `compressor: None` is the dense
/// baseline (no δ monitor). The `bottom-k` negative control runs at
/// c = 2: at c = 100 even an inverted selection leaves so little mass
/// behind that δ ≈ 1/(1 − k/n) sits inside the tolerance band — keeping
/// half the coordinates (the SMALLEST half) pushes δ toward 2.
fn leg_config(spec: &ValidateSpec, model: &str, compressor: Option<CompressorKind>) -> TrainConfig {
    let mut cfg = TrainConfig::default_for(model);
    cfg.workers = spec.workers;
    cfg.steps = spec.steps;
    cfg.seed = spec.seed;
    cfg.eval_every = 0;
    cfg.verbose = false;
    match compressor {
        None => {
            cfg.algorithm = Algorithm::Dense;
            cfg.delta_every = 0;
        }
        Some(kind) => {
            cfg.algorithm = Algorithm::Lags;
            cfg.compressor = kind;
            cfg.delta_every = spec.delta_every;
            // the gate compares against Eq. 20's EXPECTED RandK error,
            // not one draw: deterministic closed-form denominator
            cfg.delta_expectation = true;
            if kind == CompressorKind::BottomK {
                cfg.compression = 2.0;
            }
        }
    }
    cfg
}

/// Run one Lags leg and fold its δ series into a [`LegResult`].
fn run_leg(
    rt: &Arc<Runtime>,
    spec: &ValidateSpec,
    model: &str,
    kind: CompressorKind,
    dense_final_loss: f64,
) -> Result<LegResult> {
    let mut t = Trainer::with_runtime(rt, leg_config(spec, model, Some(kind)))?;
    let report = t.run()?;
    let series = t.delta_series().expect("validate legs always monitor delta");
    let names: Vec<String> = t.model_manifest().layers.iter().map(|l| l.name.clone()).collect();
    let layers: Vec<LayerDelta> = series
        .iter()
        .enumerate()
        .map(|(li, s)| LayerDelta::from_series(&names[li], s, spec.tolerance))
        .collect();
    let pass = layers.iter().all(|l| l.violations.is_empty());
    Ok(LegResult {
        model: model.to_string(),
        compressor: kind.name().to_string(),
        final_loss: report.final_loss,
        dense_final_loss,
        loss_gap: report.final_loss - dense_final_loss,
        delta_fraction_holding: report.delta_fraction_holding,
        layers,
        pass,
    })
}

/// Run the whole matrix against the artifacts in `dir` ("native" for the
/// built-in zoo). Returns the report; the caller decides the exit code
/// from `report.pass` (and writes validation.json).
pub fn run(dir: &str, spec: &ValidateSpec) -> Result<ValidationReport> {
    let mut rt = Runtime::open(dir, spec.seed)?;
    // same calibration policy as `train` without --calibrate: load an
    // existing calibration file if present, else the documented fallback
    rt.calibrate(false)?;
    let rt = Arc::new(rt);
    let mut results = Vec::new();
    for (mi, model) in spec.models.iter().enumerate() {
        // one dense same-seed baseline per model, shared by every leg
        let dense_final_loss =
            Trainer::with_runtime(&rt, leg_config(spec, model, None))?.run()?.final_loss;
        for &kind in &spec.compressors {
            results.push(run_leg(&rt, spec, model, kind, dense_final_loss)?);
        }
        if spec.inject_violation && mi == 0 {
            results.push(run_leg(&rt, spec, model, CompressorKind::BottomK, dense_final_loss)?);
        }
    }
    let pass = results.iter().all(|r| r.pass);
    Ok(ValidationReport { mode: spec.mode.clone(), tolerance: spec.tolerance, results, pass })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_the_shipped_zoo() {
        let q = ValidateSpec::quick(42);
        assert_eq!(q.compressors, ZOO.to_vec());
        assert_eq!(q.models, vec!["mlp".to_string(), "convnet".to_string()]);
        assert!(!q.inject_violation);
        let f = ValidateSpec::full(42);
        assert_eq!(f.compressors, ZOO.to_vec());
        assert_eq!(f.models.len(), 5);
        assert!(f.steps > q.steps);
        // the negative control is NOT part of either shipped matrix
        assert!(!q.compressors.contains(&CompressorKind::BottomK));
        assert!(!f.compressors.contains(&CompressorKind::BottomK));
    }

    #[test]
    fn layer_delta_flags_violations_and_degenerates() {
        let series = vec![(0, 0.5), (5, 1.0), (10, 1.149), (15, 1.2), (20, f64::INFINITY)];
        let l = LayerDelta::from_series("fc1", &series, DELTA_TOL);
        assert_eq!(l.samples, 5);
        assert_eq!(l.violations, vec![15, 20]);
        assert_eq!(l.max_delta, f64::INFINITY);
        // degenerate aggregates serialize via the tagged sentinel
        let j = l.to_json();
        assert_eq!(
            j.get("max_delta").unwrap().to_string_compact(),
            "{\"degenerate\":\"infinite\"}"
        );
        assert_eq!(j.get("violations").unwrap().as_arr().unwrap().len(), 2);
        // a NaN sample is a violation too, never silently "holding"
        let l = LayerDelta::from_series("fc1", &[(0, f64::NAN)], DELTA_TOL);
        assert_eq!(l.violations, vec![0]);
    }

    #[test]
    fn report_json_schema_is_stable() {
        let report = ValidationReport {
            mode: "quick".into(),
            tolerance: DELTA_TOL,
            results: vec![LegResult {
                model: "mlp".into(),
                compressor: "host".into(),
                final_loss: 0.5,
                dense_final_loss: 0.45,
                loss_gap: 0.05,
                delta_fraction_holding: Some(1.0),
                layers: vec![LayerDelta {
                    layer: "fc1".into(),
                    max_delta: 0.8,
                    mean_delta: 0.6,
                    samples: 6,
                    violations: vec![],
                }],
                pass: true,
            }],
            pass: true,
        };
        let j = report.to_json();
        // field names are the CI contract — schema_version pins the shape
        assert_eq!(j.get("schema_version").unwrap().as_usize().unwrap(), SCHEMA_VERSION);
        assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "quick");
        assert!(j.get("pass").unwrap().as_bool().unwrap());
        let leg = &j.get("results").unwrap().as_arr().unwrap()[0];
        for key in [
            "model",
            "compressor",
            "final_loss",
            "dense_final_loss",
            "loss_gap",
            "delta_fraction_holding",
            "layers",
            "pass",
        ] {
            assert!(leg.get(key).is_ok(), "missing leg field {key}");
        }
        let layer = &leg.get("layers").unwrap().as_arr().unwrap()[0];
        for key in ["layer", "max_delta", "mean_delta", "samples", "violations"] {
            assert!(layer.get(key).is_ok(), "missing layer field {key}");
        }
        // the whole report round-trips through the serializer
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert!(back.get("pass").unwrap().as_bool().unwrap());
        // summary line carries the PASS/FAIL verdict CI logs show
        assert!(report.results[0].summary_line().contains("PASS"));
    }

    #[test]
    fn bottomk_control_runs_at_half_compression() {
        let spec = ValidateSpec::quick(42);
        let cfg = leg_config(&spec, "mlp", Some(CompressorKind::BottomK));
        assert_eq!(cfg.compression, 2.0);
        assert!(cfg.delta_expectation);
        assert_eq!(cfg.algorithm, Algorithm::Lags);
        // shipped zoo members keep the default budget
        let cfg = leg_config(&spec, "mlp", Some(CompressorKind::QsgdTopk));
        assert_eq!(cfg.compression, 100.0);
        // the dense baseline never monitors δ
        let cfg = leg_config(&spec, "mlp", None);
        assert_eq!(cfg.algorithm, Algorithm::Dense);
        assert_eq!(cfg.delta_every, 0);
    }
}
