//! Standalone determinism-contract auditor (`lags-audit`) — the same pass
//! as `lags audit`, packaged as its own bin so CI and pre-commit hooks can
//! run it without pulling in the full coordinator CLI.
//!
//! Usage: `lags-audit [--root rust/src] [--json audit.json]`
//! Exits non-zero on any unwaived finding.

#![forbid(unsafe_code)]

use lags::analysis::audit;
use lags::util::cli::Args;
use lags::Result;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let root = args.flags.get("root").map(String::as_str).unwrap_or("rust/src");
    let json = args.flags.get("json").map(String::as_str).unwrap_or("audit.json");
    audit::run_cli(Path::new(root), Some(Path::new(json)))
}
