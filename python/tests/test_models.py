"""Layer-2 correctness: model zoo shape/grad/learning checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

ALL = ["mlp", "cnn", "grulm", "translm", "translm_e2e"]


@pytest.mark.parametrize("name", ALL)
def test_sanity(name):
    m = M.get_model(name)
    loss = M.sanity_check(m)
    assert np.isfinite(loss) and loss > 0


@pytest.mark.parametrize("name", ALL)
def test_layer_table_consistency(name):
    m = M.get_model(name)
    offs = m.offsets()
    assert offs[0] == 0
    for i in range(1, len(offs)):
        assert offs[i] == offs[i - 1] + m.layers[i - 1].size
    assert offs[-1] + m.layers[-1].size == m.d
    names = [l.name for l in m.layers]
    assert len(set(names)) == len(names), "duplicate layer names"
    assert all(l.fwd_flops >= 0 for l in m.layers)


@pytest.mark.parametrize("name", ALL)
def test_unflatten_round_trip(name):
    m = M.get_model(name)
    flat = m.init_flat(jax.random.PRNGKey(1))
    parts = m.unflatten(flat)
    re_flat = jnp.concatenate([parts[l.name].reshape(-1) for l in m.layers])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(re_flat))


def _batch(m, rng, vocab_hint=8):
    if m.x_spec.dtype == jnp.int32:
        x = jax.random.randint(rng, m.x_spec.shape, 0, vocab_hint).astype(jnp.int32)
    else:
        x = jax.random.normal(rng, m.x_spec.shape, jnp.float32)
    y = jax.random.randint(jax.random.fold_in(rng, 1), m.y_spec.shape, 0, vocab_hint)
    return x, y.astype(jnp.int32)


@pytest.mark.parametrize("name", ["mlp", "cnn", "grulm", "translm"])
def test_loss_decreases_with_sgd(name):
    """Plain SGD on one fixed batch must overfit it (loss halves)."""
    m = M.get_model(name)
    flat = m.init_flat(jax.random.PRNGKey(2))
    x, y = _batch(m, jax.random.PRNGKey(3))
    step = jax.jit(m.train_step)
    loss0, _ = step(flat, x, y)
    lr = 0.2 if name in ("mlp", "cnn") else 0.5
    for _ in range(40):
        loss, g = step(flat, x, y)
        flat = flat - lr * g
    assert float(loss) < 0.6 * float(loss0), f"{name}: {float(loss0)} -> {float(loss)}"


@pytest.mark.parametrize("name", ALL)
def test_eval_step_shapes(name):
    m = M.get_model(name)
    flat = m.init_flat(jax.random.PRNGKey(4))
    x, y = _batch(m, jax.random.PRNGKey(5))
    loss, metric = m.eval_step(flat, x, y)
    assert loss.shape == () and metric.shape == ()
    if m.metric_name == "accuracy":
        assert 0.0 <= float(metric) <= 1.0
    else:
        np.testing.assert_allclose(float(metric), float(loss), rtol=1e-5)


def test_mlp_grad_matches_finite_difference():
    m = M.make_mlp(in_dim=8, hidden=(6,), classes=3, batch=4)
    flat = m.init_flat(jax.random.PRNGKey(6))
    x, y = _batch(m, jax.random.PRNGKey(7), vocab_hint=3)
    _, g = m.train_step(flat, x, y)
    rng = np.random.default_rng(8)
    for idx in rng.choice(m.d, size=8, replace=False):
        eps = 1e-3
        e = jnp.zeros(m.d, jnp.float32).at[idx].set(eps)
        lp = float(m.loss_fn(m.unflatten(flat + e), x, y))
        lm = float(m.loss_fn(m.unflatten(flat - e), x, y))
        fd = (lp - lm) / (2 * eps)
        np.testing.assert_allclose(float(g[idx]), fd, atol=2e-3)


def test_registry_covers_defaults():
    reg = M.registry()
    for name in M.DEFAULT_MODELS:
        assert name in reg
    with pytest.raises(KeyError):
        M.get_model("nope")


def test_translm_large_config_size():
    """~110M-param config exists (lowered only with --large)."""
    m = M.registry()["translm_large"]()
    assert 80e6 < m.d < 150e6
