"""AOT path tests: manifest consistency + HLO text emission."""

import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_bucket_helpers():
    assert aot.next_pow2(1) == 1
    assert aot.next_pow2(1000) == 1024
    assert aot.next_pow2(1024) == 1024
    assert aot.bucket_for(10) == aot.MIN_BUCKET
    assert aot.bucket_for(70000) == 131072
    assert aot.pad_to(1, 4096) == 4096
    assert aot.pad_to(4096, 4096) == 4096
    assert aot.pad_to(4097, 4096) == 8192


def test_hlo_text_emission_small():
    """Lower the tiniest model end to end and check the HLO text parses as
    text (ENTRY present, param count matches)."""
    m = M.make_mlp(name="tiny", in_dim=4, hidden=(3,), classes=2, batch=2)
    pspec = jax.ShapeDtypeStruct((m.d,), jnp.float32)
    lowered = jax.jit(m.train_step).lower(pspec, m.x_spec, m.y_spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[" in text


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
class TestManifest:
    def setup_method(self):
        self.man = json.loads((ART / "manifest.json").read_text())

    def test_models_present(self):
        for name in M.DEFAULT_MODELS:
            assert name in self.man["models"]

    def test_layer_tables_match_model_defs(self):
        for name, entry in self.man["models"].items():
            m = M.get_model(name)
            assert entry["d"] == m.d
            assert len(entry["layers"]) == len(m.layers)
            off = 0
            for le, l in zip(entry["layers"], m.layers):
                assert le["name"] == l.name
                assert le["size"] == l.size
                assert le["offset"] == off
                assert le["bucket"] >= le["size"]
                off += l.size

    def test_artifact_files_exist(self):
        for entry in self.man["models"].values():
            for f in entry["files"].values():
                assert (ART / f).exists(), f
        for bucket in self.man["compress_buckets"]:
            for f in self.man["compress_files"][str(bucket)].values():
                assert (ART / f).exists(), f

    def test_buckets_cover_all_layers(self):
        buckets = set(self.man["compress_buckets"])
        for entry in self.man["models"].values():
            for le in entry["layers"]:
                assert le["bucket"] in buckets

    def test_init_bin_sizes(self):
        for entry in self.man["models"].values():
            path = ART / entry["files"]["init"]
            assert path.stat().st_size == 4 * entry["d"]

    def test_padded_dims(self):
        for entry in self.man["models"].values():
            assert entry["d_padded"] % aot.APPLY_ALIGN == 0
            assert entry["d_padded"] >= entry["d"]
