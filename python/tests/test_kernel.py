"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the AOT path: the rust runtime
executes exactly the HLO these kernels lower to, so kernel==oracle here
plus oracle==rust-host (tested on the rust side) closes the loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import apply as apply_kernel
from compile.kernels import compress as compress_kernel
from compile.kernels import ref


def _rand(n, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=n) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# compress kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1024, 4096, 65536, 131072])
@pytest.mark.parametrize("k_frac", [0.001, 0.01, 0.1, 1.0])
def test_compress_matches_ref(n, k_frac):
    k = max(1, int(n * k_frac))
    g, r = _rand(n, 1), _rand(n, 2, 0.1)
    s, nr, thr = compress_kernel.compress(g, r, 0.05, jnp.int32(k))
    es, er, ethr = ref.compress_ref(g, r, 0.05, jnp.int32(k))
    np.testing.assert_allclose(s, es, atol=1e-6)
    np.testing.assert_allclose(nr, er, atol=1e-6)
    np.testing.assert_allclose(thr, ethr, atol=1e-6)


@pytest.mark.parametrize("n,k", [(1024, 1), (1024, 1024), (2048, 2047)])
def test_compress_edge_k(n, k):
    g, r = _rand(n, 3), _rand(n, 4, 0.5)
    s, nr, _ = compress_kernel.compress(g, r, 1.0, jnp.int32(k))
    es, er, _ = ref.compress_ref(g, r, 1.0, jnp.int32(k))
    np.testing.assert_allclose(s, es, atol=1e-6)
    np.testing.assert_allclose(nr, er, atol=1e-6)


def test_compress_mass_conservation():
    """Error feedback invariant: sparse + residual' == residual + lr*grad."""
    n, k = 8192, 82
    g, r = _rand(n, 5), _rand(n, 6, 0.2)
    s, nr, _ = compress_kernel.compress(g, r, 0.1, jnp.int32(k))
    np.testing.assert_allclose(np.asarray(s) + np.asarray(nr),
                               np.asarray(r + 0.1 * g), atol=1e-6)


def test_compress_selects_at_least_k():
    n, k = 4096, 41
    g, r = _rand(n, 7), jnp.zeros(n, jnp.float32)
    s, _, thr = compress_kernel.compress(g, r, 1.0, jnp.int32(k))
    nnz = int(np.sum(np.asarray(s) != 0))
    assert nnz >= k
    # kept values are exactly those with |acc| >= thr
    acc = np.asarray(g)
    kept = np.abs(acc) >= float(thr)
    np.testing.assert_allclose(np.asarray(s), np.where(kept, acc, 0.0), atol=1e-7)


def test_compress_topk_values_are_largest():
    """The kept set dominates the dropped set in |value| (TopK semantics)."""
    n, k = 2048, 100
    g = _rand(n, 8)
    s, nr, _ = compress_kernel.compress(g, jnp.zeros(n, jnp.float32), 1.0, jnp.int32(k))
    kept = np.abs(np.asarray(s)[np.asarray(s) != 0])
    dropped = np.abs(np.asarray(nr)[np.asarray(nr) != 0])
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-7


def test_compress_all_zero_input():
    """Degenerate: all-zero acc -> thr 0, everything 'kept' as zeros."""
    n = 1024
    z = jnp.zeros(n, jnp.float32)
    s, nr, thr = compress_kernel.compress(z, z, 0.1, jnp.int32(10))
    assert float(thr) == 0.0
    np.testing.assert_array_equal(np.asarray(s), 0.0)
    np.testing.assert_array_equal(np.asarray(nr), 0.0)


@settings(max_examples=25, deadline=None)
@given(
    logn=st.integers(min_value=10, max_value=14),
    k=st.integers(min_value=1, max_value=512),
    lr=st.floats(min_value=1e-4, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_compress_hypothesis_sweep(logn, k, lr, seed):
    """Property sweep over shapes/k/lr: kernel == oracle everywhere."""
    n = 2**logn
    k = min(k, n)
    g, r = _rand(n, seed), _rand(n, seed + 1, 0.3)
    s, nr, thr = compress_kernel.compress(g, r, lr, jnp.int32(k))
    es, er, ethr = ref.compress_ref(g, r, lr, jnp.int32(k))
    np.testing.assert_allclose(s, es, atol=1e-5)
    np.testing.assert_allclose(nr, er, atol=1e-5)
    np.testing.assert_allclose(thr, ethr, atol=1e-6)


# ---------------------------------------------------------------------------
# sampled (double-sampling) compress
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [4096, 65536])
def test_compress_sampled_matches_ref(n):
    k = n // 100
    g, r = _rand(n, 9), _rand(n, 10, 0.1)
    s, nr, thr = compress_kernel.compress_sampled(g, r, 0.1, jnp.int32(k), 64)
    acc = r + 0.1 * g
    idx = jnp.arange(0, n, 64, dtype=jnp.int32)
    ethr = ref.sampled_threshold_ref(acc, jnp.int32(k), idx)
    np.testing.assert_allclose(thr, ethr, atol=1e-6)
    # mask consistency with the estimated threshold
    np.testing.assert_allclose(
        np.asarray(s), np.where(np.abs(np.asarray(acc)) >= float(thr), np.asarray(acc), 0.0),
        atol=1e-6,
    )
    np.testing.assert_allclose(np.asarray(s) + np.asarray(nr), np.asarray(acc), atol=1e-6)


def test_sampled_threshold_is_reasonable():
    """Double-sampling estimate selects within ~4x of the target k (gaussian)."""
    n, k = 65536, 655
    g = _rand(n, 11)
    s, _, _ = compress_kernel.compress_sampled(
        g, jnp.zeros(n, jnp.float32), 1.0, jnp.int32(k), 64
    )
    nnz = int(np.sum(np.asarray(s) != 0))
    assert k / 4 <= nnz <= 4 * k, f"nnz={nnz} too far from k={k}"


# ---------------------------------------------------------------------------
# apply kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d", [4096, 69632, 131072])  # incl. non-pow2 4096-multiple
@pytest.mark.parametrize("mu", [0.0, 0.9])
def test_apply_matches_ref(d, mu):
    p, m, a = _rand(d, 12), _rand(d, 13, 0.01), _rand(d, 14, 0.001)
    p1, m1 = apply_kernel.apply_update(p, m, a, mu)
    ep, em = ref.apply_ref(p, m, a, mu)
    np.testing.assert_allclose(p1, ep, atol=1e-6)
    np.testing.assert_allclose(m1, em, atol=1e-6)


def test_apply_zero_agg_is_momentum_decay():
    d = 4096
    p, m = _rand(d, 15), _rand(d, 16, 0.1)
    z = jnp.zeros(d, jnp.float32)
    p1, m1 = apply_kernel.apply_update(p, m, z, 0.5)
    np.testing.assert_allclose(np.asarray(m1), 0.5 * np.asarray(m), atol=1e-7)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p) - 0.5 * np.asarray(m), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    logd=st.integers(min_value=12, max_value=15),
    mu=st.floats(min_value=0.0, max_value=0.99),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_apply_hypothesis_sweep(logd, mu, seed):
    d = 2**logd
    p, m, a = _rand(d, seed), _rand(d, seed + 1, 0.05), _rand(d, seed + 2, 0.01)
    p1, m1 = apply_kernel.apply_update(p, m, a, mu)
    ep, em = ref.apply_ref(p, m, a, mu)
    np.testing.assert_allclose(p1, ep, atol=1e-5)
    np.testing.assert_allclose(m1, em, atol=1e-5)


# ---------------------------------------------------------------------------
# tiling helper
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,expect",
    [(1024, 1024), (65536, 65536), (131072, 65536), (69632, 4096), (4096 * 17, 4096)],
)
def test_pick_blk(n, expect):
    blk = compress_kernel.pick_blk(n)
    assert blk == expect
    assert n % blk == 0


# ---------------------------------------------------------------------------
# theory helpers (used by Assumption-1 harness)
# ---------------------------------------------------------------------------
def test_randk_expected_error_closed_form():
    """Monte-carlo RandK error matches (1 - k/d)||x||^2 (Stich et al.)."""
    d, k, trials = 512, 64, 400
    x = np.asarray(_rand(d, 17))
    rng = np.random.default_rng(18)
    errs = []
    for _ in range(trials):
        idx = rng.choice(d, size=k, replace=False)
        kept = np.zeros(d, np.float32)
        kept[idx] = x[idx]
        errs.append(np.sum((x - kept) ** 2))
    expected = float(ref.randk_expected_error_sq(jnp.asarray(x), k))
    assert abs(np.mean(errs) - expected) / expected < 0.1


def test_topk_error_beats_randk_expectation():
    """Single-vector sanity for Assumption 1: TopK error <= E[RandK error]."""
    d, k = 2048, 64
    x = _rand(d, 19)
    topk_err = float(jnp.sum((x - ref.topk_ref(x, k)) ** 2))
    randk_err = float(ref.randk_expected_error_sq(x, k))
    assert topk_err <= randk_err + 1e-6
