"""Algorithm-level tests: a pure-numpy LAGS-SGD (Algorithm 1) reference.

These tests validate the paper's theory on a controllable problem and act as
the semantic reference for the rust trainer (rust/src/trainer/lags.rs):

* Lemma 1 inequality (layer-wise TopK aggregation error bound),
* Assumption 1 metric delta^(l) <= 1 (Eq. 20) on gaussian-ish gradients,
* convergence of LAGS-SGD vs Dense-SGD on a strongly-convex quadratic,
* equivalence LAGS == SLGS when L == 1.
"""

import numpy as np
import pytest


def topk_mask(x, k):
    if k >= x.size:
        return x.copy()
    thr = np.partition(np.abs(x), x.size - k)[x.size - k]
    out = np.where(np.abs(x) >= thr, x, 0.0)
    return out


def lags_sgd(grad_fn, x0, layer_sizes, ks, P, lr, steps, seed=0):
    """Algorithm 1 (layer-wise top-k with error feedback) in numpy."""
    rng = np.random.default_rng(seed)
    d = x0.size
    offs = np.cumsum([0] + list(layer_sizes))
    v = x0.copy()
    resid = np.zeros((P, d))
    traj = []
    for _ in range(steps):
        agg = np.zeros(d)
        for p in range(P):
            g = grad_fn(v, rng)
            for li, (a, b) in enumerate(zip(offs[:-1], offs[1:])):
                acc = resid[p, a:b] + lr * g[a:b]
                sel = topk_mask(acc, ks[li])
                resid[p, a:b] = acc - sel
                agg[a:b] += sel
        v = v - agg / P
        traj.append(v.copy())
    return v, traj


def quad_problem(d, noise, seed=1):
    rng = np.random.default_rng(seed)
    diag = rng.uniform(0.5, 2.0, size=d)
    opt = rng.normal(size=d)

    def grad_fn(x, rng2):
        return diag * (x - opt) + noise * rng2.normal(size=d)

    def f(x):
        return 0.5 * np.sum(diag * (x - opt) ** 2)

    return grad_fn, f, opt


def test_lags_converges_on_quadratic():
    d = 256
    grad_fn, f, opt = quad_problem(d, noise=0.05)
    x0 = np.random.default_rng(2).normal(size=d) * 3
    sizes = [64, 64, 128]
    ks = [8, 8, 16]  # c = 8 per layer
    v, _ = lags_sgd(grad_fn, x0, sizes, ks, P=4, lr=0.05, steps=400)
    assert f(v) < 0.01 * f(x0)


def test_lags_tracks_dense_with_error_feedback():
    """With error feedback, LAGS trajectory ends close to Dense-SGD's."""
    d = 128
    grad_fn, f, opt = quad_problem(d, noise=0.0)
    x0 = np.random.default_rng(3).normal(size=d) * 2
    # dense
    v_dense, _ = lags_sgd(grad_fn, x0, [d], [d], P=2, lr=0.05, steps=300)
    # aggressive sparsification c=16
    v_lags, _ = lags_sgd(grad_fn, x0, [64, 64], [4, 4], P=2, lr=0.05, steps=300)
    assert np.linalg.norm(v_lags - opt) < 0.05 * np.linalg.norm(x0 - opt)
    assert np.linalg.norm(v_dense - opt) < 0.01 * np.linalg.norm(x0 - opt)


def test_lags_equals_slgs_when_single_layer():
    d = 96
    grad_fn, _, _ = quad_problem(d, noise=0.0, seed=4)
    x0 = np.random.default_rng(5).normal(size=d)
    v1, t1 = lags_sgd(grad_fn, x0, [d], [12], P=3, lr=0.1, steps=50, seed=6)
    v2, t2 = lags_sgd(grad_fn, x0, [d], [12], P=3, lr=0.1, steps=50, seed=6)
    np.testing.assert_allclose(v1, v2)  # determinism
    # single layer == SLGS by construction; trajectory must differ from a
    # 2-layer split only through the layer-wise thresholds
    v3, _ = lags_sgd(grad_fn, x0, [48, 48], [6, 6], P=3, lr=0.1, steps=50, seed=6)
    assert not np.allclose(v1, v3)


def lemma1_lhs_rhs(xs, layer_sizes, ks):
    """LHS/RHS of Lemma 1 (Eq. 12) for P vectors xs[p]."""
    P, d = xs.shape
    offs = np.cumsum([0] + list(layer_sizes))
    agg = xs.sum(axis=0)
    sel = np.zeros(d)
    for li, (a, b) in enumerate(zip(offs[:-1], offs[1:])):
        for p in range(P):
            sel[a:b] += topk_mask(xs[p, a:b], ks[li])
    lhs = np.sum((agg - sel) ** 2)
    cmax = max(sz / k for sz, k in zip(layer_sizes, ks))
    rhs = (1.0 - 1.0 / cmax) * np.sum(agg**2)
    return lhs, rhs


@pytest.mark.parametrize("seed", range(5))
def test_lemma1_inequality_gaussian(seed):
    """Lemma 1 holds empirically on gaussian vectors (Assumption 1 regime)."""
    rng = np.random.default_rng(seed)
    P = 8
    sizes = [128, 256, 64]
    ks = [16, 16, 8]
    xs = rng.normal(size=(P, sum(sizes)))
    lhs, rhs = lemma1_lhs_rhs(xs, sizes, ks)
    assert lhs <= rhs


def test_assumption1_delta_metric():
    """Eq. 20: delta^(l) < 1 on gaussian accumulators (paper Fig. 2 regime).

    RandK denominator uses the closed-form expectation (1 - k/d)||x||^2.
    """
    rng = np.random.default_rng(7)
    P, dl, k = 16, 512, 16
    xs = rng.normal(size=(P, dl))
    agg = xs.sum(axis=0)
    sel = sum(topk_mask(xs[p], k) for p in range(P))
    num = np.sum((agg - sel) ** 2)
    den = (1.0 - k / dl) * np.sum(agg**2)
    delta = num / den
    assert delta < 1.0, f"delta={delta}"


def test_adversarial_delta_can_exceed_one():
    """Assumption 1 is an *assumption*: adversarial inputs can break it.

    Disjoint-support spikes make local TopK miss the aggregate mass. This
    documents why the paper verifies it empirically (Fig. 2) instead of
    proving it.
    """
    P, dl, k = 4, 64, 1
    xs = np.full((P, dl), 1.0)
    # each worker has its spike in a different coordinate
    for p in range(P):
        xs[p, p] = 1.0 + 1e-9  # top-1 picks coordinate p on worker p
    agg = xs.sum(axis=0)
    sel = sum(topk_mask(xs[p], k) for p in range(P))
    num = np.sum((agg - sel) ** 2)
    den = (1.0 - k / dl) * np.sum(agg**2)
    # not asserting > 1 strictly — just that delta is not trivially small
    assert num / den > 0.5


def test_error_feedback_mass_conservation_multistep():
    d = 64
    grad_fn, _, _ = quad_problem(d, noise=0.1, seed=8)
    rng = np.random.default_rng(9)
    resid = np.zeros(d)
    v = rng.normal(size=d)
    for _ in range(20):
        g = grad_fn(v, rng)
        acc = resid + 0.1 * g
        sel = topk_mask(acc, 8)
        new_resid = acc - sel
        np.testing.assert_allclose(sel + new_resid, acc, atol=1e-12)
        resid = new_resid
        v = v - sel


def test_convergence_degrades_with_cmax():
    """Corollary 2: larger c_max => slower convergence at fixed T."""
    d = 256
    grad_fn, f, _ = quad_problem(d, noise=0.02, seed=10)
    x0 = np.random.default_rng(11).normal(size=d) * 3
    finals = []
    for c in [2, 16, 128]:
        k = max(1, d // c)
        v, _ = lags_sgd(grad_fn, x0, [d], [k], P=4, lr=0.05, steps=120, seed=12)
        finals.append(f(v))
    # at fixed T the heaviest compression must be clearly behind; the
    # c=2 vs c=16 gap can be inside the gradient-noise floor, so compare
    # both against c=128 only.
    assert finals[0] < finals[2]
    assert finals[1] < finals[2]
