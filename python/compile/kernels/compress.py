"""Layer-1 Pallas kernel: fused error-feedback accumulate + Top-k mask.

This is the compute hot-spot of LAGS-SGD (Algorithm 1, lines 7-8): per layer
``l`` every worker forms ``acc = residual + lr * grad`` and splits it into the
top-k part (communicated) and the residual (kept locally).

Structure (see DESIGN.md §Hardware-Adaptation):

* the THRESHOLD is computed once per layer outside the Pallas body (an exact
  sort by default, or the double-sampling estimate of Lin et al. 2018) — the
  analogue of DGC's sample-then-mask on GPU, avoiding a full device sort in
  the kernel;
* the MASK + RESIDUAL update is the streaming elementwise Pallas kernel,
  blocked into VMEM-sized tiles (``BLK`` elements per grid step). On a real
  TPU each grid step streams three BLK-element f32 tiles HBM->VMEM
  (grad, residual in; 2 tiles out), VPU-bound, MXU untouched.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the rust runtime can
execute the artifact (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM tile: 64k f32 elements = 256 KiB per tile; the kernel touches
# 4 tiles (grad, resid in; sparse, resid out) -> 1 MiB << 16 MiB VMEM.
BLK = 65536


def pick_blk(n: int, cap: int = BLK) -> int:
    """Largest power-of-two tile that divides n, capped at `cap`.

    Artifact sizes are padded to powers of two (compress buckets) or
    4096-multiples (apply), so this returns >= 4096 in practice.
    """
    blk = 1
    while blk * 2 <= cap and n % (blk * 2) == 0:
        blk *= 2
    return blk


def _mask_kernel(acc_ref, thr_ref, sparse_ref, out_resid_ref):
    """Elementwise tile body: split acc at |acc| >= thr (TopK mask, Eq. 4)."""
    thr = thr_ref[0]
    acc = acc_ref[...]
    keep = jnp.abs(acc) >= thr
    sparse = jnp.where(keep, acc, 0.0)
    sparse_ref[...] = sparse
    out_resid_ref[...] = acc - sparse


def _mask_pallas(acc: jnp.ndarray, thr) -> tuple:
    """Run the mask kernel over a 1-D vector, tiled in BLK chunks.

    ``acc`` is computed ONCE outside (resid + lr*grad) and reused for the
    threshold sort and the mask, so kept-set membership is bit-exact with
    the oracle (recomputing acc in-kernel can flip |acc|==thr boundaries).
    """
    n = acc.shape[0]
    blk = pick_blk(n)
    grid = n // blk
    thr = jnp.asarray(thr, jnp.float32).reshape((1,))
    out_shape = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    # thr is a per-layer scalar: every grid step maps to block 0.
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    tile_spec = pl.BlockSpec((blk,), lambda i: (i,))
    return pl.pallas_call(
        _mask_kernel,
        grid=(grid,),
        in_specs=[tile_spec, scalar_spec],
        out_specs=(tile_spec, tile_spec),
        out_shape=out_shape,
        interpret=True,
    )(acc, thr)


def compress(grad, resid, lr, k):
    """Fused LAGS compress: (grad[n], resid[n], lr, k) -> (sparse, resid', thr).

    Exact threshold (full sort over |acc|), then the Pallas mask kernel.
    Semantically identical to ref.compress_ref.
    """
    acc = resid + lr * grad  # XLA fuses this with the sort input
    thr = ref.kth_largest_abs(acc, k)
    sparse, new_resid = _mask_pallas(acc, thr)
    return sparse, new_resid, thr


def compress_sampled(grad, resid, lr, k, sample_stride: int):
    """Double-sampling variant (Lin et al. 2018): estimate thr from a strided
    subsample of |acc| instead of a full sort. O(s log s) vs O(n log n).

    The strided (deterministic) sample keeps the artifact reproducible; the
    rust host fallback uses a PRNG sample — both satisfy the same estimate
    contract tested in test_kernel.py.
    """
    n = grad.shape[0]
    acc = resid + lr * grad
    sample_idx = jnp.arange(0, n, sample_stride, dtype=jnp.int32)
    thr = ref.sampled_threshold_ref(acc, k, sample_idx)
    sparse, new_resid = _mask_pallas(acc, thr)
    return sparse, new_resid, thr


def make_compress(n: int, sampled: bool = False, sample_stride: int = 64):
    """Return a jit-able f(grad[n], resid[n], lr, k) for AOT lowering."""
    if sampled:
        fn = functools.partial(compress_sampled, sample_stride=sample_stride)
    else:
        fn = compress

    def wrapped(grad, resid, lr, k):
        sparse, new_resid, thr = fn(grad, resid, lr, k)
        return (sparse, new_resid, thr)

    return wrapped
