"""Layer-1 Pallas kernel: fused momentum-SGD parameter apply.

Algorithm 1 line 10: ``v_t = v_{t-1} - (1/P) g_t`` — plus the optional
momentum-on-aggregate variant (mu > 0) used by the momentum-correction
training trick the paper cites (Lin et al. 2018).

The aggregated update ``agg`` arriving from the rust coordinator already
contains the learning rate (folded into acc at compress time, Alg. 1 l.7)
and the 1/P averaging, so the kernel is a pure fused elementwise update:

    mom'    = mu * mom + agg
    params' = params - mom'

Tiled like compress.py: BLK-element VMEM tiles, VPU-bound, 4 tiles live.
interpret=True for CPU-PJRT executability (see compress.py docstring).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 65536


def _apply_kernel(params_ref, mom_ref, agg_ref, mu_ref, out_params_ref, out_mom_ref):
    mu = mu_ref[0]
    mom_new = mu * mom_ref[...] + agg_ref[...]
    out_mom_ref[...] = mom_new
    out_params_ref[...] = params_ref[...] - mom_new


def apply_update(params, mom, agg, mu):
    """(params[d], mom[d], agg[d], mu) -> (params', mom')."""
    from .compress import pick_blk

    d = params.shape[0]
    blk = pick_blk(d)
    grid = d // blk
    mu = jnp.asarray(mu, jnp.float32).reshape((1,))
    tile_spec = pl.BlockSpec((blk,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = (
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
    )
    return pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[tile_spec, tile_spec, tile_spec, scalar_spec],
        out_specs=(tile_spec, tile_spec),
        out_shape=out_shape,
        interpret=True,
    )(params, mom, agg, mu)


def make_apply(d: int):
    """Return a jit-able f(params[d], mom[d], agg[d], mu) for AOT lowering."""

    def wrapped(params, mom, agg, mu):
        p, m = apply_update(params, mom, agg, mu)
        return (p, m)

    return wrapped
