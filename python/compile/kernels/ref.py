"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package must match its oracle here to ~1e-6 under pytest (see
python/tests/test_kernel.py). The oracles are also the semantic reference
for the rust host-side fallbacks in rust/src/sparsify/.
"""

from __future__ import annotations

import jax.numpy as jnp


def kth_largest_abs(acc: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Exact Top-k threshold: the k-th largest |acc_i| (k is 1-based).

    Matches Eq. (4) of the paper: ``thr`` such that keeping |x_i| >= thr
    keeps (at least) k elements. ``k`` may be a traced int32 scalar.
    """
    n = acc.shape[0]
    sorted_abs = jnp.sort(jnp.abs(acc))  # ascending
    idx = jnp.clip(n - k, 0, n - 1).astype(jnp.int32)
    return jnp.take(sorted_abs, idx)


def compress_ref(grad, residual, lr, k):
    """Oracle for the fused error-feedback compress step (Alg. 1, l.7-8).

        acc      = residual + lr * grad
        thr      = k-th largest |acc|
        sparse_i = acc_i if |acc_i| >= thr else 0      (dense-masked TopK)
        resid'_i = acc_i - sparse_i

    Returns (sparse, residual', thr). ``sparse + residual' == acc`` exactly
    (error feedback conserves mass), the invariant the property tests check.
    """
    acc = residual + lr * grad
    thr = kth_largest_abs(acc, k)
    mask = jnp.abs(acc) >= thr
    sparse = jnp.where(mask, acc, 0.0)
    return sparse, acc - sparse, thr


def apply_ref(params, mom, agg, mu):
    """Oracle for the fused momentum-SGD apply.

        mom'    = mu * mom + agg
        params' = params - mom'

    ``agg`` is the aggregated (already lr-scaled, already averaged) sparse
    update (1/P) * sum_p TopK(...); with mu=0 this is Algorithm 1 line 10.
    """
    mom_new = mu * mom + agg
    return params - mom_new, mom_new


def sampled_threshold_ref(acc, k, sample_idx):
    """Oracle for the double-sampling threshold estimate (Lin et al. 2018).

    Estimate the k-th largest |acc| from a subsample: take the
    ceil(k * s / n)-th largest of the sampled |values|, where s = len(sample).
    """
    n = acc.shape[0]
    s = sample_idx.shape[0]
    sample = jnp.abs(jnp.take(acc, sample_idx))
    ks = jnp.clip((k * s + n - 1) // n, 1, s)  # ceil(k*s/n), 1-based
    sorted_s = jnp.sort(sample)
    return jnp.take(sorted_s, jnp.clip(s - ks, 0, s - 1).astype(jnp.int32))


def topk_ref(x, k):
    """Plain TopK(x, k) operator of Eq. (4) (no error feedback)."""
    thr = kth_largest_abs(x, jnp.asarray(k, jnp.int32))
    return jnp.where(jnp.abs(x) >= thr, x, 0.0)


def randk_expected_error_sq(x, k):
    """E[||x - RandK(x,k)||^2] = (1 - k/d) ||x||^2 (Stich et al. 2018).

    Used by the Assumption-1 verification harness (Eq. 20 denominator is a
    single draw; its expectation is this closed form).
    """
    d = x.shape[0]
    return (1.0 - k / d) * jnp.sum(x * x)
