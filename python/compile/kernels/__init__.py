"""Layer-1 Pallas kernels for LAGS-SGD (build-time only).

Modules:
  compress — fused error-feedback accumulate + Top-k threshold mask
  apply    — fused momentum-SGD parameter update
  ref      — pure-jnp oracles (the correctness contract)
"""

from . import apply, compress, ref  # noqa: F401
