"""AOT compile path: lower L2 models + L1 kernels to HLO text artifacts.

Interchange format is HLO **text**, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py and README.md gotchas).

Outputs (written to ``artifacts/``):
    <model>_train.hlo.txt    (params[d], x, y) -> (loss, grad[d])
    <model>_eval.hlo.txt     (params[d], x, y) -> (loss, metric)
    <model>_apply.hlo.txt    (params[dp], mom[dp], agg[dp], mu) -> (params', mom')
    <model>_init.bin         f32 little-endian initial flat params (seeded)
    compress_<n>.hlo.txt     (grad[n], resid[n], lr, k_i32) -> (sparse, resid', thr)
    manifest.json            layer tables, offsets, flops, buckets, files

Run via ``make artifacts`` (no-op when inputs unchanged).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .kernels import apply as apply_kernel
from .kernels import compress as compress_kernel

MIN_BUCKET = 1024  # smallest compress artifact; layers pad up to this
APPLY_ALIGN = 4096  # flat param dim padded to a multiple of this for apply


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def bucket_for(size: int) -> int:
    return max(MIN_BUCKET, next_pow2(size))


def pad_to(n: int, align: int) -> int:
    return ((n + align - 1) // align) * align


def lower_model(m: model_lib.ModelDef, out: pathlib.Path, seed: int) -> dict:
    """Lower train/eval/apply for one model; return its manifest entry."""
    d = m.d
    dp = pad_to(d, APPLY_ALIGN)
    pspec = jax.ShapeDtypeStruct((d,), jnp.float32)
    ppad = jax.ShapeDtypeStruct((dp,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    files = {}
    train = jax.jit(m.train_step).lower(pspec, m.x_spec, m.y_spec)
    files["train"] = f"{m.name}_train.hlo.txt"
    (out / files["train"]).write_text(to_hlo_text(train))

    ev = jax.jit(m.eval_step).lower(pspec, m.x_spec, m.y_spec)
    files["eval"] = f"{m.name}_eval.hlo.txt"
    (out / files["eval"]).write_text(to_hlo_text(ev))

    ap = jax.jit(apply_kernel.make_apply(dp)).lower(ppad, ppad, ppad, scalar)
    files["apply"] = f"{m.name}_apply.hlo.txt"
    (out / files["apply"]).write_text(to_hlo_text(ap))

    # Seeded initial parameters so rust-side runs are reproducible without jax.
    flat0 = np.asarray(m.init_flat(jax.random.PRNGKey(seed)), dtype="<f4")
    files["init"] = f"{m.name}_init.bin"
    (out / files["init"]).write_bytes(flat0.tobytes())

    offs = m.offsets()
    return {
        "name": m.name,
        "d": d,
        "d_padded": dp,
        "metric": m.metric_name,
        "classes": m.classes,
        "x": {"shape": list(m.x_spec.shape), "dtype": str(m.x_spec.dtype)},
        "y": {"shape": list(m.y_spec.shape), "dtype": str(m.y_spec.dtype)},
        "files": files,
        "layers": [
            {
                "name": l.name,
                "shape": list(l.shape),
                "size": l.size,
                "offset": offs[i],
                "bucket": bucket_for(l.size),
                "fwd_flops": l.fwd_flops,
            }
            for i, l in enumerate(m.layers)
        ],
    }


def lower_compress(n: int, out: pathlib.Path, sampled: bool) -> str:
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    k = jax.ShapeDtypeStruct((), jnp.int32)
    fn = compress_kernel.make_compress(n, sampled=sampled)
    lowered = jax.jit(fn).lower(vec, vec, lr, k)
    suffix = "s" if sampled else ""
    fname = f"compress{suffix}_{n}.hlo.txt"
    (out / fname).write_text(to_hlo_text(lowered))
    return fname


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--models",
        default=",".join(model_lib.DEFAULT_MODELS),
        help="comma-separated model names (see model.registry)",
    )
    ap.add_argument("--large", action="store_true", help="also lower translm_large (~110M)")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    names = [s for s in args.models.split(",") if s]
    if args.large and "translm_large" not in names:
        names.append("translm_large")

    manifest = {"models": {}, "compress_buckets": [], "seed": args.seed}
    buckets = set()
    for name in names:
        m = model_lib.get_model(name)
        print(f"[aot] lowering {name}: d={m.d} layers={len(m.layers)}")
        entry = lower_model(m, out, args.seed)
        manifest["models"][name] = entry
        buckets.update(l["bucket"] for l in entry["layers"])

    compress_files = {}
    for n in sorted(buckets):
        print(f"[aot] lowering compress bucket n={n}")
        compress_files[str(n)] = {
            "exact": lower_compress(n, out, sampled=False),
            "sampled": lower_compress(n, out, sampled=True),
        }
    manifest["compress_buckets"] = sorted(buckets)
    manifest["compress_files"] = compress_files

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] wrote {out}/manifest.json ({len(names)} models, {len(buckets)} buckets)")


if __name__ == "__main__":
    main()
