"""Layer-2: JAX model definitions for the LAGS-SGD reproduction.

Every model is expressed as a single flat f32 parameter vector plus a static
layer table (name, shape, offset) — exactly the representation the paper uses
(Eq. 2: x = x^(1) ⊔ x^(2) ⊔ ... ⊔ x^(L)).  The rust coordinator slices the
flat gradient at the layer offsets to perform per-layer sparsification, so
the AOT surface stays tiny:

    train_step(params[d], x, y) -> (loss, grad[d])
    eval_step (params[d], x, y) -> (loss, metric)

Model zoo (stand-ins for the paper's ResNet-20/VGG-16/ResNet-50/LSTM-PTB,
see DESIGN.md §Scale-substitutions):

    mlp          — dense classifier        (Cifar-10-like synthetic task)
    cnn          — small conv net          (conv-dominated layer profile)
    grulm        — GRU language model      (LSTM-PTB stand-in)
    translm      — transformer LM          (modern LM workload)
    translm_e2e  — ~0.8M-param transformer for the end-to-end driver
    translm_large— ~110M-param config (lowered on demand with --large)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

Shape = Tuple[int, ...]


@dataclasses.dataclass
class LayerSpec:
    """One learnable tensor = one LAGS 'layer' (paper footnote 2: frameworks
    may split a layer into weight+bias tensors; sparsification is per
    tensor)."""

    name: str
    shape: Shape
    fwd_flops: float  # per-batch forward FLOPs attributed to this tensor

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclasses.dataclass
class ModelDef:
    name: str
    layers: List[LayerSpec]
    x_spec: jax.ShapeDtypeStruct
    y_spec: jax.ShapeDtypeStruct
    loss_fn: Callable  # (params_dict, x, y) -> scalar loss
    metric_fn: Callable  # (params_dict, x, y) -> scalar metric
    metric_name: str  # "accuracy" | "ppl_loss"
    classes: int = 0  # label cardinality (classes for classifiers, vocab for LMs)

    @property
    def d(self) -> int:
        return sum(l.size for l in self.layers)

    def offsets(self) -> List[int]:
        offs, off = [], 0
        for l in self.layers:
            offs.append(off)
            off += l.size
        return offs

    # ---- flat <-> dict ---------------------------------------------------
    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out, off = {}, 0
        for l in self.layers:
            out[l.name] = flat[off : off + l.size].reshape(l.shape)
            off += l.size
        return out

    def init_flat(self, rng: jax.Array) -> jnp.ndarray:
        parts = []
        for l in self.layers:
            rng, sub = jax.random.split(rng)
            parts.append(_init_tensor(sub, l.name, l.shape).reshape(-1))
        return jnp.concatenate(parts).astype(jnp.float32)

    # ---- AOT entry points -------------------------------------------------
    def train_step(self, flat, x, y):
        def loss_of_flat(f):
            return self.loss_fn(self.unflatten(f), x, y)

        loss, grad = jax.value_and_grad(loss_of_flat)(flat)
        return (loss, grad)

    def eval_step(self, flat, x, y):
        params = self.unflatten(flat)
        return (self.loss_fn(params, x, y), self.metric_fn(params, x, y))


def _init_tensor(rng: jax.Array, name: str, shape: Shape) -> jnp.ndarray:
    """He/Glorot-style init keyed off the tensor role encoded in its name."""
    if name.endswith(".beta") or name.endswith(".b"):
        return jnp.zeros(shape, jnp.float32)
    if name.endswith(".gamma"):
        return jnp.ones(shape, jnp.float32)
    if ".emb" in name or name.startswith("emb") or name.startswith("pos"):
        return 0.02 * jax.random.normal(rng, shape, jnp.float32)
    if len(shape) >= 2:
        fan_in = int(math.prod(shape[:-1]))
        scale = math.sqrt(2.0 / max(fan_in, 1))
        return scale * jax.random.normal(rng, shape, jnp.float32)
    return jnp.zeros(shape, jnp.float32)


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels int32, logits [..., C]."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------
def make_mlp(
    name: str = "mlp",
    in_dim: int = 512,
    hidden: Tuple[int, ...] = (256, 128),
    classes: int = 10,
    batch: int = 32,
) -> ModelDef:
    dims = (in_dim,) + tuple(hidden) + (classes,)
    layers: List[LayerSpec] = []
    for i in range(len(dims) - 1):
        a, b = dims[i], dims[i + 1]
        layers.append(LayerSpec(f"fc{i}.w", (a, b), 2.0 * batch * a * b))
        layers.append(LayerSpec(f"fc{i}.b", (b,), 1.0 * batch * b))

    nlin = len(dims) - 1

    def forward(p, x):
        h = x
        for i in range(nlin):
            h = h @ p[f"fc{i}.w"] + p[f"fc{i}.b"]
            if i < nlin - 1:
                h = jax.nn.relu(h)
        return h

    def loss_fn(p, x, y):
        return _xent(forward(p, x), y)

    def metric_fn(p, x, y):
        return _accuracy(forward(p, x), y)

    return ModelDef(
        name=name,
        layers=layers,
        x_spec=jax.ShapeDtypeStruct((batch, in_dim), jnp.float32),
        y_spec=jax.ShapeDtypeStruct((batch,), jnp.int32),
        loss_fn=loss_fn,
        metric_fn=metric_fn,
        metric_name="accuracy",
        classes=classes,
    )


# ---------------------------------------------------------------------------
# CNN-lite (conv-dominated profile — the ResNet/VGG stand-in for numerics)
# ---------------------------------------------------------------------------
def make_cnn(
    name: str = "cnn",
    hw: int = 16,
    channels: Tuple[int, ...] = (16, 32, 32),
    fc_dim: int = 64,
    classes: int = 10,
    batch: int = 16,
) -> ModelDef:
    layers: List[LayerSpec] = []
    cin, res = 3, hw
    for i, cout in enumerate(channels):
        # 3x3 SAME conv, then 2x2 maxpool
        flops = 2.0 * batch * res * res * 9 * cin * cout
        layers.append(LayerSpec(f"conv{i}.w", (3, 3, cin, cout), flops))
        layers.append(LayerSpec(f"conv{i}.b", (cout,), 1.0 * batch * res * res * cout))
        cin, res = cout, res // 2
    feat = channels[-1]
    layers.append(LayerSpec("fc0.w", (feat, fc_dim), 2.0 * batch * feat * fc_dim))
    layers.append(LayerSpec("fc0.b", (fc_dim,), 1.0 * batch * fc_dim))
    layers.append(LayerSpec("fc1.w", (fc_dim, classes), 2.0 * batch * fc_dim * classes))
    layers.append(LayerSpec("fc1.b", (classes,), 1.0 * batch * classes))

    nconv = len(channels)

    def forward(p, x):
        h = x
        for i in range(nconv):
            h = jax.lax.conv_general_dilated(
                h,
                p[f"conv{i}.w"],
                window_strides=(1, 1),
                padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            h = jax.nn.relu(h + p[f"conv{i}.b"])
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        h = jax.nn.relu(h @ p["fc0.w"] + p["fc0.b"])
        return h @ p["fc1.w"] + p["fc1.b"]

    def loss_fn(p, x, y):
        return _xent(forward(p, x), y)

    def metric_fn(p, x, y):
        return _accuracy(forward(p, x), y)

    return ModelDef(
        name=name,
        layers=layers,
        x_spec=jax.ShapeDtypeStruct((batch, hw, hw, 3), jnp.float32),
        y_spec=jax.ShapeDtypeStruct((batch,), jnp.int32),
        loss_fn=loss_fn,
        metric_fn=metric_fn,
        metric_name="accuracy",
        classes=classes,
    )


# ---------------------------------------------------------------------------
# GRU language model (LSTM-PTB stand-in: embedding-dominated profile)
# ---------------------------------------------------------------------------
def make_grulm(
    name: str = "grulm",
    vocab: int = 64,
    embed: int = 64,
    hidden: int = 128,
    seq: int = 32,
    batch: int = 8,
) -> ModelDef:
    tok = 1.0 * batch * seq
    layers = [
        LayerSpec("emb.w", (vocab, embed), tok * embed),
        LayerSpec("gru.wx", (embed, 3 * hidden), 2.0 * tok * embed * 3 * hidden),
        LayerSpec("gru.wh", (hidden, 3 * hidden), 2.0 * tok * hidden * 3 * hidden),
        LayerSpec("gru.b", (3 * hidden,), tok * 3 * hidden),
        LayerSpec("proj.w", (hidden, vocab), 2.0 * tok * hidden * vocab),
        LayerSpec("proj.b", (vocab,), tok * vocab),
    ]

    def forward(p, x):
        e = p["emb.w"][x]  # [B, T, E]
        gx = e @ p["gru.wx"] + p["gru.b"]  # [B, T, 3H]
        h0 = jnp.zeros((x.shape[0], hidden), jnp.float32)

        def cell(h, gx_t):
            gh = h @ p["gru.wh"]  # [B, 3H]
            xz, xr, xn = jnp.split(gx_t, 3, axis=-1)
            hz, hr, hn = jnp.split(gh, 3, axis=-1)
            z = jax.nn.sigmoid(xz + hz)
            r = jax.nn.sigmoid(xr + hr)
            n = jnp.tanh(xn + r * hn)
            h_new = (1.0 - z) * h + z * n
            return h_new, h_new

        _, hs = jax.lax.scan(cell, h0, jnp.swapaxes(gx, 0, 1))  # [T, B, H]
        hs = jnp.swapaxes(hs, 0, 1)  # [B, T, H]
        return hs @ p["proj.w"] + p["proj.b"]

    def loss_fn(p, x, y):
        return _xent(forward(p, x), y)

    def metric_fn(p, x, y):
        # perplexity is exp(loss); report loss, exp() happens in rust
        return loss_fn(p, x, y)

    return ModelDef(
        name=name,
        layers=layers,
        x_spec=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        y_spec=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        loss_fn=loss_fn,
        metric_fn=metric_fn,
        metric_name="ppl_loss",
        classes=vocab,
    )


# ---------------------------------------------------------------------------
# Transformer language model (decoder-only, tied head)
# ---------------------------------------------------------------------------
def make_translm(
    name: str = "translm",
    vocab: int = 256,
    d_model: int = 128,
    n_layer: int = 2,
    n_head: int = 4,
    seq: int = 64,
    batch: int = 4,
) -> ModelDef:
    assert d_model % n_head == 0
    dh = d_model // n_head
    tok = 1.0 * batch * seq
    d_ff = 4 * d_model
    layers = [
        LayerSpec("emb.w", (vocab, d_model), tok * d_model),
        LayerSpec("pos.w", (seq, d_model), tok * d_model),
    ]
    for i in range(n_layer):
        pre = f"blk{i}."
        attn_flops = 2.0 * tok * d_model * d_model
        layers += [
            LayerSpec(pre + "ln1.gamma", (d_model,), tok * d_model),
            LayerSpec(pre + "ln1.beta", (d_model,), tok * d_model),
            LayerSpec(pre + "wq", (d_model, d_model), attn_flops),
            LayerSpec(pre + "wk", (d_model, d_model), attn_flops),
            LayerSpec(
                pre + "wv",
                (d_model, d_model),
                # attribute the T^2 attention matmuls to wv
                attn_flops + 4.0 * batch * n_head * seq * seq * dh,
            ),
            LayerSpec(pre + "wo", (d_model, d_model), attn_flops),
            LayerSpec(pre + "ln2.gamma", (d_model,), tok * d_model),
            LayerSpec(pre + "ln2.beta", (d_model,), tok * d_model),
            LayerSpec(pre + "w1", (d_model, d_ff), 2.0 * tok * d_model * d_ff),
            LayerSpec(pre + "b1", (d_ff,), tok * d_ff),
            LayerSpec(pre + "w2", (d_ff, d_model), 2.0 * tok * d_ff * d_model),
            LayerSpec(pre + "b2", (d_model,), tok * d_model),
        ]
    layers += [
        LayerSpec("lnf.gamma", (d_model,), tok * d_model),
        LayerSpec("lnf.beta", (d_model,), tok * d_model),
        # tied head: logits = h @ emb.w^T (flops attributed here)
        LayerSpec("head.b", (vocab,), 2.0 * tok * d_model * vocab),
    ]

    def layer_norm(h, g, b):
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.var(h, axis=-1, keepdims=True)
        return g * (h - mu) * jax.lax.rsqrt(var + 1e-5) + b

    causal = jnp.tril(jnp.ones((seq, seq), jnp.bool_))

    def attn(p, pre, h):
        B, T, D = h.shape
        q = (h @ p[pre + "wq"]).reshape(B, T, n_head, dh).transpose(0, 2, 1, 3)
        k = (h @ p[pre + "wk"]).reshape(B, T, n_head, dh).transpose(0, 2, 1, 3)
        v = (h @ p[pre + "wv"]).reshape(B, T, n_head, dh).transpose(0, 2, 1, 3)
        scores = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(dh)
        scores = jnp.where(causal[None, None, :, :], scores, -1e30)
        out = jax.nn.softmax(scores, axis=-1) @ v  # [B, nh, T, dh]
        out = out.transpose(0, 2, 1, 3).reshape(B, T, D)
        return out @ p[pre + "wo"]

    def forward(p, x):
        h = p["emb.w"][x] + p["pos.w"][None, :, :]
        for i in range(n_layer):
            pre = f"blk{i}."
            h = h + attn(p, pre, layer_norm(h, p[pre + "ln1.gamma"], p[pre + "ln1.beta"]))
            hn = layer_norm(h, p[pre + "ln2.gamma"], p[pre + "ln2.beta"])
            ff = jax.nn.gelu(hn @ p[pre + "w1"] + p[pre + "b1"]) @ p[pre + "w2"] + p[pre + "b2"]
            h = h + ff
        h = layer_norm(h, p["lnf.gamma"], p["lnf.beta"])
        return h @ p["emb.w"].T + p["head.b"]

    def loss_fn(p, x, y):
        return _xent(forward(p, x), y)

    def metric_fn(p, x, y):
        return loss_fn(p, x, y)

    return ModelDef(
        name=name,
        layers=layers,
        x_spec=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        y_spec=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        loss_fn=loss_fn,
        metric_fn=metric_fn,
        metric_name="ppl_loss",
        classes=vocab,
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def registry() -> Dict[str, Callable[[], ModelDef]]:
    return {
        "mlp": lambda: make_mlp(),
        "cnn": lambda: make_cnn(),
        "grulm": lambda: make_grulm(),
        "translm": lambda: make_translm(),
        "translm_e2e": lambda: make_translm(
            name="translm_e2e", vocab=1024, d_model=128, n_layer=3, n_head=4, seq=32, batch=4
        ),
        "translm_large": lambda: make_translm(
            name="translm_large",
            vocab=32768,
            d_model=768,
            n_layer=12,
            n_head=12,
            seq=128,
            batch=1,
        ),
    }


DEFAULT_MODELS = ["mlp", "cnn", "grulm", "translm", "translm_e2e"]


def get_model(name: str) -> ModelDef:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown model {name!r}; have {sorted(reg)}")
    m = reg[name]()
    assert m.d == sum(l.size for l in m.layers)
    return m


def sanity_check(m: ModelDef, seed: int = 0) -> float:
    """Run one train_step on random data; returns the loss (used by tests)."""
    rng = jax.random.PRNGKey(seed)
    flat = m.init_flat(rng)
    if m.x_spec.dtype == jnp.int32:
        x = jax.random.randint(rng, m.x_spec.shape, 0, 8).astype(jnp.int32)
    else:
        x = jax.random.normal(rng, m.x_spec.shape, jnp.float32)
    y = jax.random.randint(rng, m.y_spec.shape, 0, 8).astype(jnp.int32)
    loss, grad = m.train_step(flat, x, y)
    assert grad.shape == (m.d,)
    assert bool(jnp.isfinite(loss)), "non-finite loss"
    assert bool(jnp.all(jnp.isfinite(grad))), "non-finite grad"
    return float(loss)
