// placeholder
