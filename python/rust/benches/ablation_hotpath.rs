fn main() {}
