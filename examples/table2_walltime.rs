//! TAB2 harness: DES wall-clock reproduction of Table 2 (iteration time of
//! Dense / SLGS / LAGS and the S1 / S2 / S_max speedups) on the paper's
//! published model profiles at P=16, 1 Gbps Ethernet.
//!
//!     cargo run --release --example table2_walltime -- [--workers P]
//!         [--alpha F] [--bandwidth F] [--out results/table2]
//!
//! Paper reference rows (Table 2): ResNet-50 1.45/0.67/0.51 (S1 2.86,
//! S2 1.31, Smax 1.52); Inception-v4 3.85/1.60/1.25 (3.08/1.28/1.29);
//! LSTM-PTB 7.80/1.02/0.92 (8.52/1.11/1.28).

use lags::adaptive::perf_model;
use lags::collectives::NetworkModel;
use lags::metrics::ResultWriter;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::util::cli::Args;
use lags::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let net = NetworkModel {
        alpha: args.f64_or("alpha", 5e-4)?,
        bandwidth: args.f64_or("bandwidth", 111e6)?,
        workers: args.usize_or("workers", 16)?,
    };
    let paper: &[(&str, [f64; 6])] = &[
        ("resnet50", [1.45, 0.67, 0.51, 2.86, 1.31, 1.52]),
        ("inception_v4", [3.85, 1.60, 1.25, 3.08, 1.28, 1.29]),
        ("lstm_ptb", [7.80, 1.02, 0.92, 8.52, 1.11, 1.28]),
    ];
    println!("Table 2: measured(DES) vs paper — P={} 1GbE", net.workers);
    println!(
        "| {:<13} | {:>13} | {:>13} | {:>13} | {:>11} | {:>11} | {:>11} |",
        "Model", "Dense", "SLGS", "LAGS", "S1", "S2", "Smax"
    );
    let mut rows = Vec::new();
    for (name, p) in paper {
        let m = zoo::by_name(name).unwrap();
        let c = if *name == "lstm_ptb" { 250.0 } else { 1000.0 };
        let sp = SimParams::uniform(&m, c);
        let dense = simulate(&m, &net, Schedule::DensePipelined, &SimParams::dense(&m));
        let slgs = simulate(&m, &net, Schedule::Slgs, &sp);
        let lags = simulate(&m, &net, Schedule::Lags, &sp);
        let s1 = dense.iter_time / lags.iter_time;
        let s2 = slgs.iter_time / lags.iter_time;
        let smax = perf_model::smax(m.t_f, m.t_b(), slgs.t_comm);
        println!(
            "| {:<13} | {:>5.2}s vs {:>4.2} | {:>5.2}s vs {:>4.2} | {:>5.2}s vs {:>4.2} | {:>4.2} vs {:>4.2} | {:>4.2} vs {:>4.2} | {:>4.2} vs {:>4.2} |",
            name, dense.iter_time, p[0], slgs.iter_time, p[1], lags.iter_time, p[2],
            s1, p[3], s2, p[4], smax, p[5]
        );
        rows.push(Json::obj(vec![
            ("model", Json::Str(name.to_string())),
            ("dense", Json::Num(dense.iter_time)),
            ("slgs", Json::Num(slgs.iter_time)),
            ("lags", Json::Num(lags.iter_time)),
            ("s1", Json::Num(s1)),
            ("s2", Json::Num(s2)),
            ("smax", Json::Num(smax)),
            ("smax_fraction", Json::Num((s2 - 1.0) / (smax - 1.0))),
            ("paper", Json::arr_f64(p)),
        ]));
    }
    let out = args.str_or("out", "results/table2");
    ResultWriter::new(&out)?.write_json("table2.json", &Json::Arr(rows))?;
    println!("wrote {out}/table2.json");
    Ok(())
}
