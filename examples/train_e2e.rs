//! End-to-end driver (DESIGN.md §E2E): train the transformer LM through
//! the full three-layer stack — JAX-lowered fwd/bwd artifact, Pallas
//! compress/apply kernels (XLA path), rust coordinator with LAGS — on a
//! synthetic Markov corpus with P=4 workers, and log the loss curve.
//!
//!     cargo run --release --example train_e2e -- [--steps N] [--workers P]
//!         [--model translm_e2e] [--compressor xla] [--out results/e2e]
//!
//! The default config is a ~0.8M-parameter transformer (3 layers, d=128,
//! vocab 1024) — the CPU-scale stand-in for the paper's large models; a
//! ~110M config exists behind `make artifacts ARGS=--large` +
//! `--model translm_large` (documented in DESIGN.md §Scale-substitutions).
//! The run is recorded in EXPERIMENTS.md §E2E.

use lags::config::TrainConfig;
use lags::metrics::ResultWriter;
use lags::sparsify::CompressorKind;
use lags::trainer::{Algorithm, Trainer};
use lags::util::cli::Args;
use lags::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let mut cfg = TrainConfig::default_for(&args.str_or("model", "translm_e2e"));
    cfg.algorithm = Algorithm::Lags;
    cfg.workers = args.usize_or("workers", 4)?;
    cfg.steps = args.usize_or("steps", 300)?;
    cfg.lr = args.f64_or("lr", 0.25)?;
    cfg.momentum = args.f64_or("momentum", 0.9)?;
    cfg.compression = args.f64_or("compression", 50.0)?;
    cfg.eval_every = args.usize_or("eval-every", 50)?;
    cfg.eval_batches = 4;
    cfg.delta_every = args.usize_or("delta-every", 25)?;
    cfg.compressor = CompressorKind::parse(&args.str_or("compressor", "host"))?;
    cfg.verbose = true;

    eprintln!(
        "[e2e] model={} P={} steps={} c={} compressor={:?}",
        cfg.model, cfg.workers, cfg.steps, cfg.compression, cfg.compressor
    );
    let mut trainer = Trainer::from_artifacts(&args.str_or("artifacts", "artifacts"), cfg)?;
    let mm = trainer.model_manifest().clone();
    eprintln!("[e2e] d={} ({} layers); training...", mm.d, mm.layers.len());

    let t0 = lags::util::clock::now();
    let report = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== end-to-end run ===");
    println!("{}", report.summary_line());
    println!(
        "final eval loss {:.4} → perplexity {:.2} (vocab {}, chain entropy floor ≈ 1.3 nats)",
        report.final_eval_loss,
        report.final_eval_loss.exp(),
        mm.classes
    );
    if let Some(frac) = report.delta_fraction_holding {
        println!(
            "Assumption 1: delta^(l) <= 1 for {:.1}% of {} samples (max {:.3})",
            frac * 100.0,
            mm.layers.len(),
            report.delta_max.unwrap_or(f64::NAN)
        );
    }
    println!(
        "wall {wall:.1}s on 1 CPU; simulated testbed iteration {:.4}s ({:.1}% comm hidden)",
        report.sim_iter_seconds,
        100.0 * report.sim_hidden_seconds / report.sim_iter_seconds.max(1e-12)
    );

    let out = args.str_or("out", "results/e2e");
    let w = ResultWriter::new(&out)?;
    w.write_csv("loss_curve.csv", &report.curve)?;
    let mut j = report.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("wall_seconds_total".into(), Json::Num(wall));
        m.insert("d".into(), Json::Num(mm.d as f64));
    }
    w.write_json("report.json", &j)?;
    println!("wrote {out}/loss_curve.csv and report.json");
    Ok(())
}
