//! EQ18 harness: adaptive per-layer compression-ratio selection — the
//! "A" in LAGS. Shows the selected c^(l) per layer for a zoo profile, the
//! resulting DES iteration time vs a flat c_u, and the effective c_max
//! that enters the Corollary-2 convergence bound.
//!
//!     cargo run --release --example adaptive_ratios -- [--profile resnet50]
//!         [--c-max 1000] [--bandwidth 111e6] [--workers 16]

use lags::adaptive::{ratio, RatioConfig};
use lags::collectives::NetworkModel;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::util::cli::Args;
use lags::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let name = args.str_or("profile", "resnet50");
    let m = zoo::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
    let net = NetworkModel {
        alpha: args.f64_or("alpha", 5e-4)?,
        bandwidth: args.f64_or("bandwidth", 111e6)?,
        workers: args.usize_or("workers", 16)?,
    };
    let cfg = RatioConfig { c_max: args.f64_or("c-max", 1000.0)?, ..RatioConfig::default() };
    let ratios = ratio::select_ratios(&m, &net, &cfg);

    println!("Eq. 18 selection for {name} (c_u={}, P={}):", cfg.c_max, net.workers);
    println!("| {:<16} | {:>10} | {:>8} | {:>10} | {:>10} | {:>10} |",
        "layer", "d^(l)", "c^(l)", "k^(l)", "t_comm", "budget t_b(l+1)");
    for (i, (l, &c)) in m.layers.iter().zip(ratios.iter()).enumerate() {
        let k = (l.params as f64 / c).max(1.0);
        let budget = m.layers.get(i + 1).map(|n| n.t_b).unwrap_or(0.0);
        println!(
            "| {:<16} | {:>10} | {:>8.1} | {:>10.0} | {:>10} | {:>10} |",
            l.name, l.params, c, k,
            fmt_secs(net.allgather_sparse(k)),
            fmt_secs(budget)
        );
    }
    println!("\neffective c_max (Corollary 2 bound driver) = {:.1}", ratio::effective_cmax(&ratios));

    // DES: adaptive vs flat
    let mut p_ada = SimParams::uniform(&m, cfg.c_max);
    p_ada.ratios = ratios.clone();
    let flat = simulate(&m, &net, Schedule::Lags, &SimParams::uniform(&m, cfg.c_max));
    let ada = simulate(&m, &net, Schedule::Lags, &p_ada);
    let flat_bytes: f64 = flat.events.iter().map(|e| e.wire_bytes).sum();
    let ada_bytes: f64 = ada.events.iter().map(|e| e.wire_bytes).sum();
    println!("\nflat c={}: iter {:.4}s, {:.0} KB on wire", cfg.c_max, flat.iter_time, flat_bytes / 1e3);
    println!("adaptive : iter {:.4}s, {:.0} KB on wire", ada.iter_time, ada_bytes / 1e3);
    println!(
        "=> adaptive ships {:.1}x the gradient mass per iteration at {:.1}% time cost \
         (lower effective compression = tighter Corollary-2 bound = faster convergence)",
        ada_bytes / flat_bytes.max(1.0),
        100.0 * (ada.iter_time / flat.iter_time - 1.0)
    );
    Ok(())
}
