//! FIG2 harness: verify Assumption 1 by measuring delta^(l) (Eq. 20) per
//! layer during LAGS-SGD training, plus the training loss — the paper's
//! Fig. 2, on the live models (mlp / cnn / grulm as the ResNet-20 /
//! VGG-16 / LSTM-PTB stand-ins) with P=16 workers.
//!
//!     cargo run --release --example fig2_delta -- [--steps N] [--workers P]
//!
//! Output: results/fig2/<model>_delta.csv (per-layer series),
//!         results/fig2/<model>_loss.csv, summary on stdout.

use lags::config::TrainConfig;
use lags::metrics::{CurveRecorder, ResultWriter};
use lags::trainer::{Algorithm, Trainer};
use lags::util::cli::Args;
use lags::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let steps = args.usize_or("steps", 60)?;
    let workers = args.usize_or("workers", 16)?;
    let rt = std::sync::Arc::new(lags::runtime::Runtime::load(
        args.str_or("artifacts", "artifacts"),
    )?);
    let w = ResultWriter::new(args.str_or("out", "results/fig2"))?;

    let mut summary = Vec::new();
    for (model, c, lr) in [("mlp", 100.0, 0.1), ("cnn", 50.0, 0.1), ("grulm", 100.0, 0.5)] {
        let mut cfg = TrainConfig::default_for(model);
        cfg.algorithm = Algorithm::Lags;
        cfg.workers = workers;
        cfg.steps = steps;
        cfg.lr = lr;
        cfg.compression = c;
        cfg.delta_every = 5;
        cfg.eval_every = 0;
        let mut t = Trainer::with_runtime(&rt, cfg)?;
        let report = t.run()?;
        let frac = report.delta_fraction_holding.unwrap();
        let dmax = report.delta_max.unwrap();
        println!(
            "{model:<7} P={workers} c={c:<5} steps={steps}: delta<=1 for {:.1}% of samples, \
             max delta {dmax:.4}, final loss {:.4}",
            frac * 100.0,
            report.final_loss
        );

        // per-layer delta CSV (7 largest layers, like the paper's figure)
        let series = t.delta_series().unwrap();
        let mm = t.model_manifest();
        let mut order: Vec<usize> = (0..mm.layers.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(mm.layers[i].size));
        order.truncate(7);
        let names: Vec<String> = order.iter().map(|&i| mm.layers[i].name.clone()).collect();
        let cols: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut rec = CurveRecorder::new(&cols);
        if let Some(first) = series.get(order[0]) {
            for (row, &(step, _)) in first.iter().enumerate() {
                let vals: Vec<f64> = order
                    .iter()
                    .map(|&li| series[li].get(row).map(|&(_, d)| d).unwrap_or(f64::NAN))
                    .collect();
                rec.push(step, &vals);
            }
        }
        w.write_csv(&format!("{model}_delta.csv"), &rec)?;
        w.write_csv(&format!("{model}_loss.csv"), &report.curve)?;
        summary.push(Json::obj(vec![
            ("model", Json::Str(model.into())),
            ("fraction_holding", Json::Num(frac)),
            ("max_delta", Json::Num(dmax)),
            ("final_loss", Json::Num(report.final_loss)),
        ]));
    }
    w.write_json("summary.json", &Json::Arr(summary))?;
    println!("wrote results/fig2/");
    Ok(())
}
