//! FIG1 harness: render the per-iteration timelines of the three schedules
//! (Fig. 1a/b/c) as ASCII Gantt charts over the DES events.
//!
//!     cargo run --release --example fig1_timeline -- [--profile resnet50]
//!         [--compression 1000] [--width 100]

use lags::collectives::NetworkModel;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, IterationBreakdown, Schedule, SimParams};
use lags::util::cli::Args;

fn gantt(b: &IterationBreakdown, width: usize) {
    let span = b.iter_time;
    let scale = |t: f64| ((t / span) * (width as f64 - 1.0)) as usize;
    // compute bar
    let mut comp = vec![' '; width];
    for cell in comp.iter_mut().take(scale(b.t_f)) {
        *cell = 'F';
    }
    for cell in comp.iter_mut().take(scale(b.t_f + b.t_b)).skip(scale(b.t_f)) {
        *cell = 'B';
    }
    println!("  comp |{}|", comp.iter().collect::<String>());
    // comm bar
    let mut comm = vec![' '; width];
    for e in &b.events {
        for cell in comm.iter_mut().take(scale(e.end).min(width)).skip(scale(e.start)) {
            *cell = '#';
        }
    }
    println!("  comm |{}|  iter = {:.3}s, hidden = {:.3}s", comm.iter().collect::<String>(), b.iter_time, b.hidden);
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let name = args.str_or("profile", "resnet50");
    let m = zoo::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
    let net = NetworkModel::gige_16().with_workers(args.usize_or("workers", 16)?);
    let c = args.f64_or("compression", 1000.0)?;
    let width = args.usize_or("width", 100)?;

    println!("Fig. 1 timelines for {name} (P={}, c={c}):  F=fwd B=bwd #=comm\n", net.workers);
    for (sched, label, p) in [
        (Schedule::DensePipelined, "(a) Dense-SGD, layer-wise pipelined", SimParams::dense(&m)),
        (Schedule::Slgs, "(b) SLGS-SGD, single-shot sparse", SimParams::uniform(&m, c)),
        (Schedule::Lags, "(c) LAGS-SGD, layer-wise pipelined sparse", SimParams::uniform(&m, c)),
    ] {
        println!("{label}");
        let b = simulate(&m, &net, sched, &p);
        gantt(&b, width);
        println!();
    }
    Ok(())
}
