//! TAB1 harness: final evaluation metric of Dense / SLGS / LAGS under the
//! same training budget — the paper's Table 1 (top-1 accuracy for CNNs,
//! perplexity for the LM), on the synthetic stand-in tasks.
//!
//!     cargo run --release --example table1_accuracy -- [--steps N] [--workers P]

use lags::config::TrainConfig;
use lags::metrics::ResultWriter;
use lags::trainer::{Algorithm, Trainer};
use lags::util::cli::Args;
use lags::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let steps = args.usize_or("steps", 200)?;
    let workers = args.usize_or("workers", 8)?;
    let rt = std::sync::Arc::new(lags::runtime::Runtime::load(
        args.str_or("artifacts", "artifacts"),
    )?);
    let w = ResultWriter::new(args.str_or("out", "results/table1"))?;

    println!("Table 1 reproduction (synthetic tasks, P={workers}, {steps} steps)");
    println!(
        "| {:<8} | {:<11} | {:>9} | {:>9} | {:>9} | {:>11} |",
        "Model", "metric", "Dense", "SLGS", "LAGS", "LAGS+tricks"
    );
    let mut rows = Vec::new();
    for (model, c, lr) in [("mlp", 100.0, 0.1), ("cnn", 50.0, 0.1), ("grulm", 100.0, 0.5)] {
        let mut finals = Vec::new();
        let mut metric_name = String::new();
        // fourth column: LAGS + the paper-cited tricks (warm-up + momentum
        // correction, Lin et al. 2018) that close the sparsification gap
        for (alg, tricks) in [
            (Algorithm::Dense, false),
            (Algorithm::Slgs, false),
            (Algorithm::Lags, false),
            (Algorithm::Lags, true),
        ] {
            let mut cfg = TrainConfig::default_for(model);
            cfg.algorithm = alg;
            cfg.workers = workers;
            cfg.steps = steps;
            cfg.lr = lr;
            cfg.compression = c;
            cfg.eval_every = steps;
            cfg.eval_batches = 8;
            if tricks {
                cfg.local_momentum = 0.5;
                cfg.warmup_steps = steps / 4;
                // keep the effective step size comparable: lr * (1 - mu)
                cfg.lr = lr * (1.0 - cfg.local_momentum);
            }
            let mut t = Trainer::with_runtime(&rt, cfg)?;
            let r = t.run()?;
            metric_name = r.headline_name().to_string();
            finals.push(r.headline_metric());
            let mut j = r.to_json();
            if let lags::util::json::Json::Obj(m) = &mut j {
                m.insert("tricks".into(), Json::Bool(tricks));
            }
            rows.push(j);
        }
        println!(
            "| {:<8} | {:<11} | {:>9.4} | {:>9.4} | {:>9.4} | {:>11.4} |",
            model, metric_name, finals[0], finals[1], finals[2], finals[3]
        );
    }
    w.write_json("table1.json", &Json::Arr(rows))?;
    println!("wrote results/table1/table1.json");
    println!("(paper Table 1: the three algorithms reach near-identical final metrics;");
    println!(" expect the same closeness here, on different absolute values — synthetic data)");
    Ok(())
}
