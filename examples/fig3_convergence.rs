//! FIG3 harness: convergence comparison of Dense-SGD vs SLGS-SGD vs
//! LAGS-SGD under the same number of steps and identical hyper-parameters
//! — the paper's Fig. 3, on the synthetic Cifar-10-like (mlp, cnn) and
//! PTB-like (grulm) tasks.
//!
//!     cargo run --release --example fig3_convergence -- [--steps N] [--workers P]
//!
//! Output: results/fig3/<model>_<alg>.csv curves + merged summary.

use lags::config::TrainConfig;
use lags::metrics::ResultWriter;
use lags::trainer::{Algorithm, Trainer};
use lags::util::cli::Args;
use lags::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let steps = args.usize_or("steps", 150)?;
    let workers = args.usize_or("workers", 8)?;
    let rt = std::sync::Arc::new(lags::runtime::Runtime::load(
        args.str_or("artifacts", "artifacts"),
    )?);
    let w = ResultWriter::new(args.str_or("out", "results/fig3"))?;

    let mut rows = Vec::new();
    for (model, c, lr) in [("mlp", 100.0, 0.1), ("cnn", 50.0, 0.1), ("grulm", 100.0, 0.5)] {
        println!("--- {model} (c = {c}, P = {workers}, {steps} steps) ---");
        for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
            let mut cfg = TrainConfig::default_for(model);
            cfg.algorithm = alg;
            cfg.workers = workers;
            cfg.steps = steps;
            cfg.lr = lr;
            cfg.compression = c;
            cfg.eval_every = (steps / 10).max(1);
            cfg.eval_batches = 4;
            let mut t = Trainer::with_runtime(&rt, cfg)?;
            let r = t.run()?;
            println!("  {}", r.summary_line());
            w.write_csv(&format!("{model}_{}.csv", alg.name()), &r.curve)?;
            rows.push(r.to_json());
        }
    }
    w.write_json("summary.json", &Json::Arr(rows))?;
    println!("wrote results/fig3/");
    Ok(())
}
