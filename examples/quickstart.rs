//! Quickstart: train a small MLP with LAGS-SGD on 4 logical workers.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the minimal public-API path: load artifacts → configure →
//! train → inspect the report. Runs against `make artifacts` output when
//! present, otherwise against the built-in native zoo (same contract) —
//! so this example doubles as the CI smoke test.

use lags::config::TrainConfig;
use lags::trainer::{Algorithm, Trainer};

fn main() -> anyhow::Result<()> {
    // 1. configure: model + algorithm + cluster size
    let mut cfg = TrainConfig::default_for("mlp");
    cfg.algorithm = Algorithm::Lags;
    cfg.workers = 4;
    cfg.steps = 100;
    cfg.lr = 0.1;
    cfg.compression = 100.0; // keep top 1% of each layer
    cfg.eval_every = 25;
    cfg.verbose = true;

    // 2. load the AOT artifacts (train/eval/apply/compress executables),
    //    or the pure-rust native zoo when none are compiled — the same
    //    probe the CLI uses
    let dir = lags::runtime::default_artifacts_dir();
    if dir == "native" {
        eprintln!("note: no ./artifacts/manifest.json; using the built-in native zoo");
    }
    let mut trainer = Trainer::from_artifacts(dir, cfg)?;

    // 3. train
    let report = trainer.run()?;

    // 4. results
    println!("\n=== quickstart result ===");
    println!("{}", report.summary_line());
    println!(
        "communication: {:.1} KB/iter sparse vs {:.1} KB/iter dense equivalent ({:.1}x reduction)",
        report.msg_stats.bytes_per_iter() / 1e3,
        (trainer.model_manifest().d * 8) as f64 / 1e3,
        (trainer.model_manifest().d * 8) as f64 / report.msg_stats.bytes_per_iter()
    );
    println!(
        "simulated iteration on the paper's 16-node 1GbE testbed: {:.4}s ({:.4}s comm hidden)",
        report.sim_iter_seconds, report.sim_hidden_seconds
    );
    Ok(())
}
